//! Fine-grained run-time simulation (paper §5.3, Algorithm 1).
//!
//! Executes every IP's state machine subject to inter-IP data dependencies:
//! an idle IP enters its next state once every in-edge has delivered the
//! bits that state needs; it stays busy for the state's `cycles`, then
//! deposits its outputs, possibly unblocking consumers. Latency therefore
//! *includes* inter-IP pipeline overlap, which the coarse mode's critical
//! path deliberately ignores (Fig. 7's 15-vs-7-cycle toy example — see
//! `experiments::fig7` and this module's tests).
//!
//! The paper's Algorithm 1 steps one clock cycle at a time. Because node
//! eligibility only changes when some state completes, an event-driven
//! schedule visiting exactly those instants is cycle-exact while running
//! orders of magnitude faster; `simulate` implements that (and the
//! `cycle_accurate` test cross-checks it against a literal per-cycle
//! stepper on randomized graphs).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::{bail, Result};

use crate::graph::{Graph, NodeId};

/// Per-IP simulation outcome.
#[derive(Debug, Clone, Default)]
pub struct NodeSim {
    /// Cycles spent busy executing states.
    pub busy_cycles: u64,
    /// Cycles spent idle *waiting for inputs* while work remained
    /// (Algorithm 1's `ip.idle_cycles`).
    pub idle_cycles: u64,
    /// Cycle at which the IP finished its last state.
    pub finish_cycle: u64,
    /// Number of states executed.
    pub states_run: u64,
    /// Fraction of the makespan this IP spent busy — the per-stage
    /// utilization a batched sweep optimizes (lowest-occupancy stage is the
    /// throughput bottleneck).
    pub occupancy: f64,
}

/// Fine-grained mode output.
#[derive(Debug, Clone)]
pub struct FineReport {
    /// Total cycles until every IP stored its last outputs (Algorithm 1's
    /// `cycles`).
    pub cycles: u64,
    pub latency_ms: f64,
    /// Dynamic energy (identical to the coarse mode's — energy does not
    /// depend on the schedule) plus leakage over the *simulated* latency.
    pub energy_pj: f64,
    pub per_node: Vec<NodeSim>,
    /// Algorithm 1 line 22: the IP with minimum idle cycles — the pipeline
    /// bottleneck stage-2 optimization targets.
    pub bottleneck: NodeId,
    /// Optional execution trace (small graphs only): `(node, state_index,
    /// start_cycle, end_cycle)`.
    pub trace: Vec<(NodeId, u64, u64, u64)>,
    /// Number of inferences simulated in flight (1 for [`simulate`]).
    pub batch: u64,
    /// Cycle at which the *first* inference completed — the pipeline fill
    /// transient. Equals `cycles` when `batch == 1`.
    pub fill_cycles: u64,
    /// Steady-state inter-completion period: cycles between the last two
    /// inference completions once the pipeline is full. Equals `cycles`
    /// when `batch == 1`, so `steady_fps` degenerates to `1/latency`.
    pub steady_period_cycles: u64,
}

impl FineReport {
    /// Idle-cycle total of the bottleneck IP (Fig. 12's metric).
    pub fn bottleneck_idle(&self) -> u64 {
        self.per_node[self.bottleneck].idle_cycles
    }

    /// Sustained throughput in inferences/s: once the pipeline is full, one
    /// inference drains every `steady_period_cycles`. For `batch == 1`
    /// this is exactly `1000 / latency_ms` (no overlap information).
    pub fn steady_fps(&self) -> f64 {
        if self.cycles == 0 || self.steady_period_cycles == 0 {
            return 0.0;
        }
        let ms_per_cycle = self.latency_ms / self.cycles as f64;
        1000.0 / (self.steady_period_cycles as f64 * ms_per_cycle)
    }

    /// Makespan divided by the batch — the average per-inference latency a
    /// batched run observes (fill amortized away as `batch` grows).
    pub fn latency_per_inference_ms(&self) -> f64 {
        self.latency_ms / self.batch as f64
    }
}

/// Hard cap on retained trace events, mirroring the obs ring's 1M-event
/// cap: `--trace-out` on a big graph (or a big batch) must not grow memory
/// without bound. Drops are surfaced on the `fine.trace.dropped` counter.
pub const MAX_TRACE_EVENTS: usize = 1 << 20;

fn trace_push(tr: &mut Vec<(NodeId, u64, u64, u64)>, ev: (NodeId, u64, u64, u64)) {
    if tr.len() < MAX_TRACE_EVENTS {
        tr.push(ev);
    } else {
        crate::obs::metrics::counter("fine.trace.dropped", 1);
    }
}

struct NodeRt {
    /// Flat index of the next state to run.
    cursor: u64,
    total_states: u64,
    /// Cycle at which the node last became idle (for idle accounting).
    idle_since: u64,
    busy: bool,
    /// Whether the initial warm-up period has completed.
    warmed: bool,
}

/// Run the fine-grained simulation. `leakage_mw` is charged over simulated
/// wall-clock; pass the technology's value (or 0.0 for cycle-only studies).
/// `trace` enables per-state tracing (keep off for big graphs).
pub fn simulate(g: &Graph, leakage_mw: f64, trace: bool) -> Result<FineReport> {
    g.validate()?;
    simulate_prevalidated(g, leakage_mw, trace)
}

/// [`simulate`] without the structural re-validation — for hot loops
/// (stage-2 iterations) where the graph was just built by a template and
/// validated once. Deadlock detection still runs, so an invalid graph
/// errors rather than hanging.
pub fn simulate_prevalidated(g: &Graph, leakage_mw: f64, trace: bool) -> Result<FineReport> {
    let n = g.nodes.len();
    let mut avail = vec![0u64; g.edges.len()]; // bits delivered per edge
    let mut used = vec![0u64; g.edges.len()]; // bits consumed per edge
    let mut rt: Vec<NodeRt> = g
        .nodes
        .iter()
        .map(|node| NodeRt {
            cursor: 0,
            total_states: node.sm.num_states(),
            idle_since: 0,
            busy: false,
            warmed: false,
        })
        .collect();
    let mut sim = vec![NodeSim::default(); n];
    let mut tr = Vec::new();

    // Completion events: (cycle, node).
    let mut heap: BinaryHeap<Reverse<(u64, NodeId)>> = BinaryHeap::new();
    // Warm-up: every IP spends `warmup_cycles` configuring before its first
    // state (paper l1/l2); modeled as an initial busy period.
    for (i, node) in g.nodes.iter().enumerate() {
        if rt[i].total_states == 0 {
            sim[i].finish_cycle = 0;
            continue;
        }
        rt[i].busy = true;
        heap.push(Reverse((node.warmup_cycles, i)));
    }

    // Consumers of each edge (each edge has exactly one consumer node).
    let consumers: Vec<NodeId> = g.edges.iter().map(|e| e.to).collect();

    let try_start = |i: usize,
                     g: &Graph,
                     rt: &mut [NodeRt],
                     avail: &[u64],
                     used: &mut [u64],
                     sim: &mut [NodeSim],
                     heap: &mut BinaryHeap<Reverse<(u64, NodeId)>>,
                     tr: &mut Vec<(NodeId, u64, u64, u64)>,
                     now: u64,
                     trace: bool| {
        if rt[i].busy || rt[i].cursor >= rt[i].total_states {
            return;
        }
        let st = g.nodes[i].sm.state_at(rt[i].cursor).expect("cursor in range");
        let ready = st.needs.iter().all(|(e, b)| avail[e] - used[e] >= b);
        if !ready {
            return;
        }
        for (e, b) in st.needs.iter() {
            used[e] += b;
        }
        sim[i].idle_cycles += now - rt[i].idle_since;
        sim[i].busy_cycles += st.cycles;
        rt[i].busy = true;
        if trace {
            trace_push(tr, (i, rt[i].cursor, now, now + st.cycles));
        }
        heap.push(Reverse((now + st.cycles, i)));
    };

    // Initial pass happens implicitly through the warmup events.
    let mut last_event = 0u64;
    while let Some(Reverse((now, i))) = heap.pop() {
        last_event = last_event.max(now);
        let mut credited: Vec<usize> = Vec::new();
        if !rt[i].warmed {
            // First completion = warm-up finished; no outputs.
            rt[i].warmed = true;
        } else {
            // A real state completed: deposit outputs, advance cursor.
            let st = g.nodes[i].sm.state_at(rt[i].cursor).expect("state");
            for (e, b) in st.emits.iter() {
                avail[e] += b;
                credited.push(e);
            }
            rt[i].cursor += 1;
            sim[i].states_run += 1;
            if rt[i].cursor == rt[i].total_states {
                sim[i].finish_cycle = now;
            }
        }
        rt[i].busy = false;
        rt[i].idle_since = now;

        // The node itself may start its next state immediately…
        try_start(i, g, &mut rt, &avail, &mut used, &mut sim, &mut heap, &mut tr, now, trace);
        // …and consumers of freshly credited edges may unblock.
        for e in credited {
            let c = consumers[e];
            try_start(c, g, &mut rt, &avail, &mut used, &mut sim, &mut heap, &mut tr, now, trace);
        }
    }

    // Deadlock / starvation check: every node must have finished.
    for (i, r) in rt.iter().enumerate() {
        if r.cursor < r.total_states {
            bail!(
                "fine sim deadlock: node '{}' stuck at state {}/{} (inputs never arrived)",
                g.nodes[i].name,
                r.cursor,
                r.total_states
            );
        }
    }

    let cycles = last_event;
    let latency_ms = cycles as f64 / (g.freq_mhz * 1e3);
    let dynamic: f64 = g.nodes.iter().map(|n| n.energy_pj()).sum();
    let energy_pj = dynamic + leakage_mw * latency_ms * 1e6;
    for s in sim.iter_mut() {
        s.occupancy = if cycles > 0 { s.busy_cycles as f64 / cycles as f64 } else { 0.0 };
    }
    // Bottleneck: minimum idle cycles among IPs that did work.
    let bottleneck = (0..n)
        .filter(|&i| rt[i].total_states > 0)
        .min_by_key(|&i| sim[i].idle_cycles)
        .unwrap_or(0);
    Ok(FineReport {
        cycles,
        latency_ms,
        energy_pj,
        per_node: sim,
        bottleneck,
        trace: tr,
        batch: 1,
        fill_cycles: cycles,
        steady_period_cycles: cycles,
    })
}

/// Simulate `batch` inferences in flight through one design: every IP's
/// state machine repeats `batch` times back-to-back (warm-up runs once),
/// so downstream stages of inference `r` overlap upstream stages of
/// inference `r+1` — exactly equivalent to [`simulate`] on a graph whose
/// machines were unrolled `batch`× (see [`StateMachine::unrolled`]).
///
/// The performance core: instead of O(batch · states) events, the engine
/// watches per-IP round-completion deltas and, once the pipeline's
/// periodic steady state is provably reached (every unfinished IP's delta
/// equals its structural rate bound, or every delta has stabilized for
/// loop-throttled graphs the fluid bound cannot predict), extrapolates the
/// remaining rounds in closed form — cycle-exactly, as the property tests
/// cross-check against the literal unrolled reference. Cost is
/// O(fill + a few periods) regardless of `batch`; graphs that never settle
/// fall back to the exact full simulation (counted on
/// `fine.batched.fallback` vs `fine.batched.steady_hit`).
///
/// [`StateMachine::unrolled`]: crate::graph::StateMachine::unrolled
pub fn simulate_batched(g: &Graph, batch: usize, leakage_mw: f64, trace: bool) -> Result<FineReport> {
    g.validate()?;
    simulate_batched_prevalidated(g, batch, leakage_mw, trace)
}

/// [`simulate_batched`] without the structural re-validation — the stage-2
/// hot-loop variant, mirroring [`simulate_prevalidated`].
pub fn simulate_batched_prevalidated(
    g: &Graph,
    batch: usize,
    leakage_mw: f64,
    trace: bool,
) -> Result<FineReport> {
    // A batch of one *is* the plain simulation (byte-identical by
    // construction — property-tested over the zoo).
    if batch <= 1 {
        return simulate_prevalidated(g, leakage_mw, trace);
    }
    let _span = crate::obs::span("fine.batched");
    let b = batch as u64;
    let n = g.nodes.len();
    let orig: Vec<u64> = g.nodes.iter().map(|x| x.sm.num_states()).collect();
    let active = orig.iter().filter(|&&s| s > 0).count();

    // Steady-state detection is only attempted when every edge is balanced
    // per round (producer deposits exactly what its consumer drains).
    // Surplus edges accumulate backlog, letting the consumer's rhythm keep
    // drifting — those graphs run the exact fallback.
    let mut emit_of = vec![0u64; g.edges.len()];
    let mut need_of = vec![0u64; g.edges.len()];
    for node in &g.nodes {
        for (e, v) in node.sm.total_emits() {
            emit_of[e] += v;
        }
        for (e, v) in node.sm.total_needs() {
            need_of[e] += v;
        }
    }
    let balanced = emit_of.iter().zip(&need_of).all(|(e, d)| e == d);

    // Structural per-round rate bound: an IP's steady inter-round delta is
    // at least its own busy time, and (balance) at least every supplier's
    // delta. Fixed-point max-propagation, because sync edges close cycles
    // the topological order cannot see.
    let mut d_struct: Vec<u64> = g.nodes.iter().map(|x| x.sm.total_cycles()).collect();
    if balanced {
        for _ in 0..=n {
            let mut changed = false;
            for (ei, e) in g.edges.iter().enumerate() {
                if emit_of[ei] > 0 && d_struct[e.from] > d_struct[e.to] {
                    d_struct[e.to] = d_struct[e.from];
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
    }

    let mut avail = vec![0u64; g.edges.len()];
    let mut used = vec![0u64; g.edges.len()];
    let mut rt: Vec<NodeRt> = orig
        .iter()
        .map(|&s| NodeRt { cursor: 0, total_states: b * s, idle_since: 0, busy: false, warmed: false })
        .collect();
    let mut sim = vec![NodeSim::default(); n];
    let mut tr = Vec::new();
    let mut heap: BinaryHeap<Reverse<(u64, NodeId)>> = BinaryHeap::new();
    for (i, node) in g.nodes.iter().enumerate() {
        if orig[i] == 0 {
            sim[i].finish_cycle = 0;
            continue;
        }
        rt[i].busy = true;
        heap.push(Reverse((node.warmup_cycles, i)));
    }
    let consumers: Vec<NodeId> = g.edges.iter().map(|e| e.to).collect();

    let try_start = |i: usize,
                     g: &Graph,
                     rt: &mut [NodeRt],
                     avail: &[u64],
                     used: &mut [u64],
                     sim: &mut [NodeSim],
                     heap: &mut BinaryHeap<Reverse<(u64, NodeId)>>,
                     tr: &mut Vec<(NodeId, u64, u64, u64)>,
                     now: u64,
                     trace: bool| {
        if rt[i].busy || rt[i].cursor >= rt[i].total_states {
            return;
        }
        let st = g.nodes[i].sm.state_at(rt[i].cursor % orig[i]).expect("cursor in range");
        let ready = st.needs.iter().all(|(e, bits)| avail[e] - used[e] >= bits);
        if !ready {
            return;
        }
        for (e, bits) in st.needs.iter() {
            used[e] += bits;
        }
        sim[i].idle_cycles += now - rt[i].idle_since;
        sim[i].busy_cycles += st.cycles;
        rt[i].busy = true;
        if trace {
            trace_push(tr, (i, rt[i].cursor, now, now + st.cycles));
        }
        heap.push(Reverse((now + st.cycles, i)));
    };

    // Round-completion bookkeeping: rf[i][r] = cycle IP i finished its r-th
    // inference; t_boundary[r] = cycle *every* IP had finished round r
    // (t_boundary[0] is the fill transient).
    let mut rf: Vec<Vec<u64>> = vec![Vec::new(); n];
    let mut done_count: Vec<usize> = vec![0; batch];
    let mut t_boundary: Vec<u64> = Vec::with_capacity(batch);
    let mut steady: Option<Vec<u64>> = None;

    let mut last_event = 0u64;
    'events: while let Some(Reverse((now, i))) = heap.pop() {
        last_event = last_event.max(now);
        let mut credited: Vec<usize> = Vec::new();
        if !rt[i].warmed {
            rt[i].warmed = true;
        } else {
            let st = g.nodes[i].sm.state_at(rt[i].cursor % orig[i]).expect("state");
            for (e, bits) in st.emits.iter() {
                avail[e] += bits;
                credited.push(e);
            }
            rt[i].cursor += 1;
            sim[i].states_run += 1;
            if rt[i].cursor == rt[i].total_states {
                sim[i].finish_cycle = now;
            }
            if rt[i].cursor % orig[i] == 0 {
                rf[i].push(now);
                done_count[rf[i].len() - 1] += 1;
                while let Some(&cnt) = done_count.get(t_boundary.len()) {
                    if cnt < active {
                        break;
                    }
                    let r = t_boundary.len();
                    t_boundary.push(now);
                    if balanced && r >= 1 && r + 1 < batch {
                        if let Some(d) = steady_deltas(&rf, &orig, &d_struct, b, r) {
                            steady = Some(d);
                            break 'events;
                        }
                    }
                }
            }
        }
        rt[i].busy = false;
        rt[i].idle_since = now;

        try_start(i, g, &mut rt, &avail, &mut used, &mut sim, &mut heap, &mut tr, now, trace);
        for e in credited {
            let c = consumers[e];
            try_start(c, g, &mut rt, &avail, &mut used, &mut sim, &mut heap, &mut tr, now, trace);
        }
    }

    if let Some(deltas) = steady {
        crate::obs::metrics::counter("fine.batched.steady_hit", 1);
        // Closed-form extrapolation from each IP's simulated frontier: an
        // IP at its steady delta finishes round r at rf[k] + (r-k)·d.
        let mut finals = vec![0u64; n];
        for i in 0..n {
            if orig[i] == 0 {
                continue;
            }
            let k = rf[i].len() - 1;
            finals[i] = if k as u64 == b - 1 {
                rf[i][k] // already simulated every round — exact as-is
            } else {
                rf[i][k] + (b - 1 - k as u64) * deltas[i]
            };
        }
        let cycles = finals.iter().copied().max().unwrap_or(0);
        // The steady period is the gap between the last two inference
        // completions, T_{B-1} - T_{B-2}, both available analytically.
        let mut t_prev = 0u64;
        for i in 0..n {
            if orig[i] == 0 {
                continue;
            }
            let want = (b - 2) as usize;
            let f = if rf[i].len() > want {
                rf[i][want]
            } else {
                let k = rf[i].len() - 1;
                rf[i][k] + (b - 2 - k as u64) * deltas[i]
            };
            t_prev = t_prev.max(f);
        }
        for (i, s) in sim.iter_mut().enumerate() {
            if orig[i] == 0 {
                *s = NodeSim::default();
                continue;
            }
            // Exact closed forms: the timeline from 0 to an IP's finish is
            // exactly warm-up + busy + the idle gaps the engine accrues at
            // every state start.
            let busy = b * g.nodes[i].sm.total_cycles();
            *s = NodeSim {
                busy_cycles: busy,
                idle_cycles: finals[i].saturating_sub(g.nodes[i].warmup_cycles + busy),
                finish_cycle: finals[i],
                states_run: b * orig[i],
                occupancy: 0.0,
            };
        }
        return finalize_batched(g, b, leakage_mw, cycles, sim, tr, t_boundary[0], cycles - t_prev);
    }

    // No steady state detected: the loop ran every round — the result is
    // the literal unrolled simulation (exact by construction).
    for (i, r) in rt.iter().enumerate() {
        if r.cursor < r.total_states {
            bail!(
                "fine sim deadlock: node '{}' stuck at state {}/{} (inputs never arrived)",
                g.nodes[i].name,
                r.cursor,
                r.total_states
            );
        }
    }
    crate::obs::metrics::counter("fine.batched.fallback", 1);
    let cycles = last_event;
    let fill = t_boundary.first().copied().unwrap_or(cycles);
    let period = if t_boundary.len() >= 2 {
        t_boundary[t_boundary.len() - 1] - t_boundary[t_boundary.len() - 2]
    } else {
        cycles
    };
    finalize_batched(g, b, leakage_mw, cycles, sim, tr, fill, period)
}

/// Steady-state test at boundary `r` (all IPs have completed inference
/// `r`). Tier 1: every unfinished IP's latest inter-round delta equals its
/// structural rate bound — the delta's provable floor, so the rhythm can
/// never change again. Tier 2 (r ≥ 2, for rate patterns the fluid bound
/// cannot predict, e.g. sync-token loops): every unfinished IP's last two
/// deltas agree. Returns the per-IP extrapolation deltas on success.
fn steady_deltas(rf: &[Vec<u64>], orig: &[u64], d_struct: &[u64], b: u64, r: usize) -> Option<Vec<u64>> {
    let n = orig.len();
    let mut out = vec![0u64; n];
    let mut tier1 = true;
    for i in 0..n {
        if orig[i] == 0 {
            continue;
        }
        let k = rf[i].len() - 1;
        if k as u64 == b - 1 {
            continue; // finished: exact data, no delta needed
        }
        let d = rf[i][k] - rf[i][k - 1];
        if d != d_struct[i] {
            tier1 = false;
            break;
        }
        out[i] = d;
    }
    if tier1 {
        return Some(out);
    }
    if r < 2 {
        return None;
    }
    let mut out = vec![0u64; n];
    for i in 0..n {
        if orig[i] == 0 {
            continue;
        }
        let k = rf[i].len() - 1;
        if k as u64 == b - 1 {
            continue;
        }
        if k < 2 {
            return None;
        }
        let d1 = rf[i][k] - rf[i][k - 1];
        if d1 != rf[i][k - 1] - rf[i][k - 2] {
            return None;
        }
        out[i] = d1;
    }
    Some(out)
}

#[allow(clippy::too_many_arguments)]
fn finalize_batched(
    g: &Graph,
    b: u64,
    leakage_mw: f64,
    cycles: u64,
    mut sim: Vec<NodeSim>,
    tr: Vec<(NodeId, u64, u64, u64)>,
    fill_cycles: u64,
    steady_period_cycles: u64,
) -> Result<FineReport> {
    let latency_ms = cycles as f64 / (g.freq_mhz * 1e3);
    // Warm-up energy is paid once; control/MAC/bit energy scales with the
    // batch (identical to `energy_pj()` of the unrolled machine, modulo
    // float association).
    let dynamic: f64 = g
        .nodes
        .iter()
        .map(|x| x.energy_pj() + (b - 1) as f64 * (x.energy_pj() - x.warmup_pj))
        .sum();
    let energy_pj = dynamic + leakage_mw * latency_ms * 1e6;
    for s in sim.iter_mut() {
        s.occupancy = if cycles > 0 { s.busy_cycles as f64 / cycles as f64 } else { 0.0 };
    }
    let bottleneck = (0..g.nodes.len())
        .filter(|&i| g.nodes[i].sm.num_states() > 0)
        .min_by_key(|&i| sim[i].idle_cycles)
        .unwrap_or(0);
    Ok(FineReport {
        cycles,
        latency_ms,
        energy_pj,
        per_node: sim,
        bottleneck,
        trace: tr,
        batch: b,
        fill_cycles,
        steady_period_cycles,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{bare_node, Graph, State, StateMachine};
    use crate::ip::{ComputeKind, IpClass, Precision};

    fn comp(name: &str) -> crate::graph::Node {
        bare_node(
            name,
            IpClass::Compute { kind: ComputeKind::AdderTree, unroll: 1, prec: Precision::new(8, 8) },
        )
    }

    /// Two IPs, producer 3 states × 2 cycles, consumer 3 states × 1 cycle.
    fn pipeline2() -> Graph {
        let mut g = Graph::new("p2", 100.0);
        let a = g.add_node(comp("a"));
        let b = g.add_node(comp("b"));
        let e = g.connect(a, b);
        g.nodes[a].sm.repeat(3, State::new(2).emitting(e, 8));
        g.nodes[b].sm.repeat(3, State::new(1).needing(e, 8));
        g
    }

    #[test]
    fn pipelined_latency_overlaps() {
        let g = pipeline2();
        let r = simulate(&g, 0.0, false).unwrap();
        // a completes at 2,4,6; b runs 2-3, 4-5, 6-7 → 7 cycles total.
        assert_eq!(r.cycles, 7);
        // Coarse critical path would be 6 + 3 = 9.
        assert_eq!(g.critical_path().unwrap().0, 9);
        // b waited 2 cycles at the start + 1 + 1 between states.
        assert_eq!(r.per_node[1].idle_cycles, 4);
        assert_eq!(r.per_node[0].idle_cycles, 0);
        assert_eq!(r.bottleneck, 0);
    }

    #[test]
    fn warmup_delays_start() {
        let mut g = pipeline2();
        g.nodes[0].warmup_cycles = 10;
        let r = simulate(&g, 0.0, false).unwrap();
        assert_eq!(r.cycles, 17);
    }

    #[test]
    fn deadlock_detected() {
        let mut g = Graph::new("d", 100.0);
        let a = g.add_node(comp("a"));
        let b = g.add_node(comp("b"));
        let e = g.connect(a, b);
        // a emits 4 bits total but b needs 8 → validate() catches it.
        g.nodes[a].sm.push(State::new(1).emitting(e, 4));
        g.nodes[b].sm.push(State::new(1).needing(e, 8));
        assert!(simulate(&g, 0.0, false).is_err());
    }

    #[test]
    fn independent_nodes_run_concurrently() {
        let mut g = Graph::new("i", 100.0);
        let a = g.add_node(comp("a"));
        let b = g.add_node(comp("b"));
        g.nodes[a].sm.repeat(5, State::new(3));
        g.nodes[b].sm.repeat(5, State::new(4));
        let r = simulate(&g, 0.0, false).unwrap();
        assert_eq!(r.cycles, 20); // max(15, 20)
    }

    #[test]
    fn trace_records_states() {
        let g = pipeline2();
        let r = simulate(&g, 0.0, true).unwrap();
        assert_eq!(r.trace.len(), 6);
        // First consumer state starts at cycle 2.
        let b0 = r.trace.iter().find(|t| t.0 == 1 && t.1 == 0).unwrap();
        assert_eq!(b0.2, 2);
    }

    #[test]
    fn batch_of_one_is_byte_identical() {
        let g = pipeline2();
        let plain = simulate(&g, 1.5, true).unwrap();
        let batched = simulate_batched(&g, 1, 1.5, true).unwrap();
        assert_eq!(format!("{plain:?}"), format!("{batched:?}"));
        assert_eq!(plain.batch, 1);
        assert_eq!(plain.fill_cycles, plain.cycles);
        assert_eq!(plain.steady_period_cycles, plain.cycles);
    }

    #[test]
    fn batched_matches_unrolled_reference() {
        let g = pipeline2();
        for b in [2u64, 3, 8, 64] {
            let fast = simulate_batched(&g, b as usize, 0.0, false).unwrap();
            let lit = simulate(&g.unrolled_batch(b), 0.0, false).unwrap();
            assert_eq!(fast.cycles, lit.cycles, "batch {b}");
            assert_eq!(
                format!("{:?}", fast.per_node),
                format!("{:?}", lit.per_node),
                "batch {b}"
            );
            assert_eq!(fast.bottleneck, lit.bottleneck);
            assert_eq!(fast.batch, b);
        }
    }

    #[test]
    fn batched_fill_and_steady_period() {
        // Producer emits every 2 cycles forever; consumer drains 1 cycle
        // behind. First inference lands at 7, then one every 6 cycles.
        let g = pipeline2();
        let r = simulate_batched(&g, 8, 0.0, false).unwrap();
        assert_eq!(r.fill_cycles, 7);
        assert_eq!(r.steady_period_cycles, 6);
        assert_eq!(r.cycles, 6 * 8 + 1);
        // Steady throughput beats 1/latency-of-one: 1 every 6 cycles vs 7.
        let single = simulate(&g, 0.0, false).unwrap();
        assert!(r.steady_fps() > single.steady_fps());
        // Per-stage occupancy: the producer is the saturated stage.
        assert!(r.per_node[0].occupancy > r.per_node[1].occupancy);
        assert!((r.per_node[0].occupancy - 48.0 / 49.0).abs() < 1e-12);
    }

    #[test]
    fn batched_energy_scales_with_batch_warmup_once() {
        let mut g = pipeline2();
        g.nodes[0].warmup_pj = 100.0;
        g.nodes[0].ctrl_pj_per_state = 2.0;
        let e1 = simulate_batched(&g, 1, 0.0, false).unwrap().energy_pj;
        let e4 = simulate_batched(&g, 4, 0.0, false).unwrap().energy_pj;
        // e1 = 100 + 3·2; e4 = 100 + 12·2 (warm-up once, states ×4).
        assert!((e1 - 106.0).abs() < 1e-9);
        assert!((e4 - 124.0).abs() < 1e-9);
    }

    #[test]
    fn sync_loop_batched_matches_reference() {
        // A sync-token feedback loop (the folded-accelerator pattern the
        // templates use): a's second phase each round waits for b's token,
        // so the steady period is loop-latency-bound — the fluid rate
        // bound cannot predict it and detection must use delta stability
        // (or fall back), staying cycle-exact either way.
        let mut g = Graph::new("loop", 100.0);
        let a = g.add_node(comp("a"));
        let b = g.add_node(comp("b"));
        let e_ab = g.connect(a, b);
        let e_sync = g.connect_sync(b, a);
        g.nodes[a].sm.push(State::new(2).emitting(e_ab, 8));
        g.nodes[a].sm.push(State::new(2).needing(e_sync, 1).emitting(e_ab, 8));
        g.nodes[b].sm.push(State::new(3).needing(e_ab, 8).emitting(e_sync, 1));
        g.nodes[b].sm.push(State::new(3).needing(e_ab, 8));
        for batch in [2u64, 3, 5, 16] {
            let fast = simulate_batched(&g, batch as usize, 0.0, false).unwrap();
            let lit = simulate(&g.unrolled_batch(batch), 0.0, false).unwrap();
            assert_eq!(fast.cycles, lit.cycles, "batch {batch}");
            assert_eq!(
                format!("{:?}", fast.per_node),
                format!("{:?}", lit.per_node),
                "batch {batch}"
            );
        }
    }

    #[test]
    fn trace_buffer_is_capped() {
        let mut full = vec![(0usize, 0u64, 0u64, 0u64); MAX_TRACE_EVENTS];
        trace_push(&mut full, (1, 2, 3, 4));
        assert_eq!(full.len(), MAX_TRACE_EVENTS, "push past the cap must drop");
        let mut small = Vec::new();
        trace_push(&mut small, (1, 2, 3, 4));
        assert_eq!(small, vec![(1, 2, 3, 4)]);
    }

    /// Literal per-cycle stepper implementing Algorithm 1 verbatim, used to
    /// cross-check the event-driven engine.
    fn simulate_percycle(g: &Graph) -> u64 {
        let n = g.nodes.len();
        let mut avail = vec![0u64; g.edges.len()];
        let mut used = vec![0u64; g.edges.len()];
        let mut cursor = vec![0u64; n];
        let total: Vec<u64> = g.nodes.iter().map(|x| x.sm.num_states()).collect();
        let mut busy_left: Vec<u64> = g.nodes.iter().map(|x| x.warmup_cycles).collect();
        let mut warming: Vec<bool> = busy_left.iter().map(|&b| b > 0).collect();
        let mut cycle = 0u64;
        let mut pending_emit: Vec<Option<u64>> = vec![None; n]; // state idx being executed
        loop {
            if (0..n).all(|i| cursor[i] >= total[i]) {
                return cycle;
            }
            // Phase A (at time `cycle`): idle nodes try to start. Runs
            // before advancing time so a completion at instant t is visible
            // to starters at instant t — matching the event engine.
            for i in 0..n {
                if busy_left[i] > 0 || warming[i] || cursor[i] >= total[i] {
                    continue;
                }
                let st = g.nodes[i].sm.state_at(cursor[i]).unwrap();
                if st.needs.iter().all(|(e, b)| avail[e] - used[e] >= b) {
                    for (e, b) in st.needs.iter() {
                        used[e] += b;
                    }
                    pending_emit[i] = Some(cursor[i]);
                    busy_left[i] = st.cycles;
                }
            }
            cycle += 1;
            assert!(cycle < 1_000_000, "per-cycle reference diverged");
            // Phase B: advance busy nodes; completions land at `cycle`.
            for i in 0..n {
                if total[i] == 0 {
                    continue;
                }
                if busy_left[i] > 0 {
                    busy_left[i] -= 1;
                    if busy_left[i] == 0 {
                        if warming[i] {
                            warming[i] = false;
                        } else if let Some(s) = pending_emit[i].take() {
                            let st = g.nodes[i].sm.state_at(s).unwrap();
                            for (e, b) in st.emits.iter() {
                                avail[e] += b;
                            }
                            cursor[i] += 1;
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn cycle_accurate_vs_reference_on_random_graphs() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xF1FE);
        for case in 0..60 {
            // Random layered DAG.
            let mut g = Graph::new("r", 100.0);
            let layers = rng.range(2, 4);
            let mut prev: Vec<usize> = Vec::new();
            let mut edges_of: Vec<Vec<usize>> = Vec::new();
            for l in 0..layers {
                let width = rng.range(1, 3);
                let mut cur = Vec::new();
                for w in 0..width {
                    let id = g.add_node(comp(&format!("n{l}_{w}")));
                    g.nodes[id].warmup_cycles = rng.range(0, 3) as u64;
                    cur.push(id);
                }
                if l > 0 {
                    for &c in &cur {
                        // connect from 1..=2 random parents
                        for _ in 0..rng.range(1, 2.min(prev.len())) {
                            let p = *rng.choose(&prev);
                            let e = g.connect(p, c);
                            edges_of.push(vec![p, c, e]);
                        }
                    }
                }
                prev = cur;
            }
            // State machines: producers emit on all out-edges.
            let outs = g.out_edges();
            let ins = g.in_edges();
            for i in 0..g.nodes.len() {
                let states = rng.range(1, 4) as u64;
                let mut st = State::new(rng.range(1, 5) as u64);
                for &e in &outs[i] {
                    st = st.emitting(e, 8);
                }
                for &e in &ins[i] {
                    st = st.needing(e, 8);
                }
                let mut m = StateMachine::new();
                // Consumers must not need more than producers emit:
                // equalize state counts via min with producer counts later;
                // simplest: same count everywhere.
                m.repeat(states, st);
                g.nodes[i].sm = m;
            }
            // Equalize: set every node's state count to the min over graph
            // so flow conservation holds.
            let minc = g.nodes.iter().map(|x| x.sm.num_states()).min().unwrap();
            for node in &mut g.nodes {
                let proto = node.sm.phases[0].proto.clone();
                let mut m = StateMachine::new();
                m.repeat(minc, proto);
                node.sm = m;
            }
            if g.validate().is_err() {
                continue;
            }
            let ev = simulate(&g, 0.0, false).unwrap().cycles;
            let pc = simulate_percycle(&g);
            assert_eq!(ev, pc, "case {case}: event={ev} percycle={pc}");
        }
    }
}
