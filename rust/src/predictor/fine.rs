//! Fine-grained run-time simulation (paper §5.3, Algorithm 1).
//!
//! Executes every IP's state machine subject to inter-IP data dependencies:
//! an idle IP enters its next state once every in-edge has delivered the
//! bits that state needs; it stays busy for the state's `cycles`, then
//! deposits its outputs, possibly unblocking consumers. Latency therefore
//! *includes* inter-IP pipeline overlap, which the coarse mode's critical
//! path deliberately ignores (Fig. 7's 15-vs-7-cycle toy example — see
//! `experiments::fig7` and this module's tests).
//!
//! The paper's Algorithm 1 steps one clock cycle at a time. Because node
//! eligibility only changes when some state completes, an event-driven
//! schedule visiting exactly those instants is cycle-exact while running
//! orders of magnitude faster; `simulate` implements that (and the
//! `cycle_accurate` test cross-checks it against a literal per-cycle
//! stepper on randomized graphs).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::{bail, Result};

use crate::graph::{Graph, NodeId};

/// Per-IP simulation outcome.
#[derive(Debug, Clone, Default)]
pub struct NodeSim {
    /// Cycles spent busy executing states.
    pub busy_cycles: u64,
    /// Cycles spent idle *waiting for inputs* while work remained
    /// (Algorithm 1's `ip.idle_cycles`).
    pub idle_cycles: u64,
    /// Cycle at which the IP finished its last state.
    pub finish_cycle: u64,
    /// Number of states executed.
    pub states_run: u64,
}

/// Fine-grained mode output.
#[derive(Debug, Clone)]
pub struct FineReport {
    /// Total cycles until every IP stored its last outputs (Algorithm 1's
    /// `cycles`).
    pub cycles: u64,
    pub latency_ms: f64,
    /// Dynamic energy (identical to the coarse mode's — energy does not
    /// depend on the schedule) plus leakage over the *simulated* latency.
    pub energy_pj: f64,
    pub per_node: Vec<NodeSim>,
    /// Algorithm 1 line 22: the IP with minimum idle cycles — the pipeline
    /// bottleneck stage-2 optimization targets.
    pub bottleneck: NodeId,
    /// Optional execution trace (small graphs only): `(node, state_index,
    /// start_cycle, end_cycle)`.
    pub trace: Vec<(NodeId, u64, u64, u64)>,
}

impl FineReport {
    /// Idle-cycle total of the bottleneck IP (Fig. 12's metric).
    pub fn bottleneck_idle(&self) -> u64 {
        self.per_node[self.bottleneck].idle_cycles
    }
}

struct NodeRt {
    /// Flat index of the next state to run.
    cursor: u64,
    total_states: u64,
    /// Cycle at which the node last became idle (for idle accounting).
    idle_since: u64,
    busy: bool,
    /// Whether the initial warm-up period has completed.
    warmed: bool,
}

/// Run the fine-grained simulation. `leakage_mw` is charged over simulated
/// wall-clock; pass the technology's value (or 0.0 for cycle-only studies).
/// `trace` enables per-state tracing (keep off for big graphs).
pub fn simulate(g: &Graph, leakage_mw: f64, trace: bool) -> Result<FineReport> {
    g.validate()?;
    simulate_prevalidated(g, leakage_mw, trace)
}

/// [`simulate`] without the structural re-validation — for hot loops
/// (stage-2 iterations) where the graph was just built by a template and
/// validated once. Deadlock detection still runs, so an invalid graph
/// errors rather than hanging.
pub fn simulate_prevalidated(g: &Graph, leakage_mw: f64, trace: bool) -> Result<FineReport> {
    let n = g.nodes.len();
    let mut avail = vec![0u64; g.edges.len()]; // bits delivered per edge
    let mut used = vec![0u64; g.edges.len()]; // bits consumed per edge
    let mut rt: Vec<NodeRt> = g
        .nodes
        .iter()
        .map(|node| NodeRt {
            cursor: 0,
            total_states: node.sm.num_states(),
            idle_since: 0,
            busy: false,
            warmed: false,
        })
        .collect();
    let mut sim = vec![NodeSim::default(); n];
    let mut tr = Vec::new();

    // Completion events: (cycle, node).
    let mut heap: BinaryHeap<Reverse<(u64, NodeId)>> = BinaryHeap::new();
    // Warm-up: every IP spends `warmup_cycles` configuring before its first
    // state (paper l1/l2); modeled as an initial busy period.
    for (i, node) in g.nodes.iter().enumerate() {
        if rt[i].total_states == 0 {
            sim[i].finish_cycle = 0;
            continue;
        }
        rt[i].busy = true;
        heap.push(Reverse((node.warmup_cycles, i)));
    }

    // Consumers of each edge (each edge has exactly one consumer node).
    let consumers: Vec<NodeId> = g.edges.iter().map(|e| e.to).collect();

    let try_start = |i: usize,
                     g: &Graph,
                     rt: &mut [NodeRt],
                     avail: &[u64],
                     used: &mut [u64],
                     sim: &mut [NodeSim],
                     heap: &mut BinaryHeap<Reverse<(u64, NodeId)>>,
                     tr: &mut Vec<(NodeId, u64, u64, u64)>,
                     now: u64,
                     trace: bool| {
        if rt[i].busy || rt[i].cursor >= rt[i].total_states {
            return;
        }
        let st = g.nodes[i].sm.state_at(rt[i].cursor).expect("cursor in range");
        let ready = st.needs.iter().all(|(e, b)| avail[e] - used[e] >= b);
        if !ready {
            return;
        }
        for (e, b) in st.needs.iter() {
            used[e] += b;
        }
        sim[i].idle_cycles += now - rt[i].idle_since;
        sim[i].busy_cycles += st.cycles;
        rt[i].busy = true;
        if trace {
            tr.push((i, rt[i].cursor, now, now + st.cycles));
        }
        heap.push(Reverse((now + st.cycles, i)));
    };

    // Initial pass happens implicitly through the warmup events.
    let mut last_event = 0u64;
    while let Some(Reverse((now, i))) = heap.pop() {
        last_event = last_event.max(now);
        let mut credited: Vec<usize> = Vec::new();
        if !rt[i].warmed {
            // First completion = warm-up finished; no outputs.
            rt[i].warmed = true;
        } else {
            // A real state completed: deposit outputs, advance cursor.
            let st = g.nodes[i].sm.state_at(rt[i].cursor).expect("state");
            for (e, b) in st.emits.iter() {
                avail[e] += b;
                credited.push(e);
            }
            rt[i].cursor += 1;
            sim[i].states_run += 1;
            if rt[i].cursor == rt[i].total_states {
                sim[i].finish_cycle = now;
            }
        }
        rt[i].busy = false;
        rt[i].idle_since = now;

        // The node itself may start its next state immediately…
        try_start(i, g, &mut rt, &avail, &mut used, &mut sim, &mut heap, &mut tr, now, trace);
        // …and consumers of freshly credited edges may unblock.
        for e in credited {
            let c = consumers[e];
            try_start(c, g, &mut rt, &avail, &mut used, &mut sim, &mut heap, &mut tr, now, trace);
        }
    }

    // Deadlock / starvation check: every node must have finished.
    for (i, r) in rt.iter().enumerate() {
        if r.cursor < r.total_states {
            bail!(
                "fine sim deadlock: node '{}' stuck at state {}/{} (inputs never arrived)",
                g.nodes[i].name,
                r.cursor,
                r.total_states
            );
        }
    }

    let cycles = last_event;
    let latency_ms = cycles as f64 / (g.freq_mhz * 1e3);
    let dynamic: f64 = g.nodes.iter().map(|n| n.energy_pj()).sum();
    let energy_pj = dynamic + leakage_mw * latency_ms * 1e6;
    // Bottleneck: minimum idle cycles among IPs that did work.
    let bottleneck = (0..n)
        .filter(|&i| rt[i].total_states > 0)
        .min_by_key(|&i| sim[i].idle_cycles)
        .unwrap_or(0);
    Ok(FineReport { cycles, latency_ms, energy_pj, per_node: sim, bottleneck, trace: tr })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{bare_node, Graph, State, StateMachine};
    use crate::ip::{ComputeKind, IpClass, Precision};

    fn comp(name: &str) -> crate::graph::Node {
        bare_node(
            name,
            IpClass::Compute { kind: ComputeKind::AdderTree, unroll: 1, prec: Precision::new(8, 8) },
        )
    }

    /// Two IPs, producer 3 states × 2 cycles, consumer 3 states × 1 cycle.
    fn pipeline2() -> Graph {
        let mut g = Graph::new("p2", 100.0);
        let a = g.add_node(comp("a"));
        let b = g.add_node(comp("b"));
        let e = g.connect(a, b);
        g.nodes[a].sm.repeat(3, State::new(2).emitting(e, 8));
        g.nodes[b].sm.repeat(3, State::new(1).needing(e, 8));
        g
    }

    #[test]
    fn pipelined_latency_overlaps() {
        let g = pipeline2();
        let r = simulate(&g, 0.0, false).unwrap();
        // a completes at 2,4,6; b runs 2-3, 4-5, 6-7 → 7 cycles total.
        assert_eq!(r.cycles, 7);
        // Coarse critical path would be 6 + 3 = 9.
        assert_eq!(g.critical_path().unwrap().0, 9);
        // b waited 2 cycles at the start + 1 + 1 between states.
        assert_eq!(r.per_node[1].idle_cycles, 4);
        assert_eq!(r.per_node[0].idle_cycles, 0);
        assert_eq!(r.bottleneck, 0);
    }

    #[test]
    fn warmup_delays_start() {
        let mut g = pipeline2();
        g.nodes[0].warmup_cycles = 10;
        let r = simulate(&g, 0.0, false).unwrap();
        assert_eq!(r.cycles, 17);
    }

    #[test]
    fn deadlock_detected() {
        let mut g = Graph::new("d", 100.0);
        let a = g.add_node(comp("a"));
        let b = g.add_node(comp("b"));
        let e = g.connect(a, b);
        // a emits 4 bits total but b needs 8 → validate() catches it.
        g.nodes[a].sm.push(State::new(1).emitting(e, 4));
        g.nodes[b].sm.push(State::new(1).needing(e, 8));
        assert!(simulate(&g, 0.0, false).is_err());
    }

    #[test]
    fn independent_nodes_run_concurrently() {
        let mut g = Graph::new("i", 100.0);
        let a = g.add_node(comp("a"));
        let b = g.add_node(comp("b"));
        g.nodes[a].sm.repeat(5, State::new(3));
        g.nodes[b].sm.repeat(5, State::new(4));
        let r = simulate(&g, 0.0, false).unwrap();
        assert_eq!(r.cycles, 20); // max(15, 20)
    }

    #[test]
    fn trace_records_states() {
        let g = pipeline2();
        let r = simulate(&g, 0.0, true).unwrap();
        assert_eq!(r.trace.len(), 6);
        // First consumer state starts at cycle 2.
        let b0 = r.trace.iter().find(|t| t.0 == 1 && t.1 == 0).unwrap();
        assert_eq!(b0.2, 2);
    }

    /// Literal per-cycle stepper implementing Algorithm 1 verbatim, used to
    /// cross-check the event-driven engine.
    fn simulate_percycle(g: &Graph) -> u64 {
        let n = g.nodes.len();
        let mut avail = vec![0u64; g.edges.len()];
        let mut used = vec![0u64; g.edges.len()];
        let mut cursor = vec![0u64; n];
        let total: Vec<u64> = g.nodes.iter().map(|x| x.sm.num_states()).collect();
        let mut busy_left: Vec<u64> = g.nodes.iter().map(|x| x.warmup_cycles).collect();
        let mut warming: Vec<bool> = busy_left.iter().map(|&b| b > 0).collect();
        let mut cycle = 0u64;
        let mut pending_emit: Vec<Option<u64>> = vec![None; n]; // state idx being executed
        loop {
            if (0..n).all(|i| cursor[i] >= total[i]) {
                return cycle;
            }
            // Phase A (at time `cycle`): idle nodes try to start. Runs
            // before advancing time so a completion at instant t is visible
            // to starters at instant t — matching the event engine.
            for i in 0..n {
                if busy_left[i] > 0 || warming[i] || cursor[i] >= total[i] {
                    continue;
                }
                let st = g.nodes[i].sm.state_at(cursor[i]).unwrap();
                if st.needs.iter().all(|(e, b)| avail[e] - used[e] >= b) {
                    for (e, b) in st.needs.iter() {
                        used[e] += b;
                    }
                    pending_emit[i] = Some(cursor[i]);
                    busy_left[i] = st.cycles;
                }
            }
            cycle += 1;
            assert!(cycle < 1_000_000, "per-cycle reference diverged");
            // Phase B: advance busy nodes; completions land at `cycle`.
            for i in 0..n {
                if total[i] == 0 {
                    continue;
                }
                if busy_left[i] > 0 {
                    busy_left[i] -= 1;
                    if busy_left[i] == 0 {
                        if warming[i] {
                            warming[i] = false;
                        } else if let Some(s) = pending_emit[i].take() {
                            let st = g.nodes[i].sm.state_at(s).unwrap();
                            for (e, b) in st.emits.iter() {
                                avail[e] += b;
                            }
                            cursor[i] += 1;
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn cycle_accurate_vs_reference_on_random_graphs() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xF1FE);
        for case in 0..60 {
            // Random layered DAG.
            let mut g = Graph::new("r", 100.0);
            let layers = rng.range(2, 4);
            let mut prev: Vec<usize> = Vec::new();
            let mut edges_of: Vec<Vec<usize>> = Vec::new();
            for l in 0..layers {
                let width = rng.range(1, 3);
                let mut cur = Vec::new();
                for w in 0..width {
                    let id = g.add_node(comp(&format!("n{l}_{w}")));
                    g.nodes[id].warmup_cycles = rng.range(0, 3) as u64;
                    cur.push(id);
                }
                if l > 0 {
                    for &c in &cur {
                        // connect from 1..=2 random parents
                        for _ in 0..rng.range(1, 2.min(prev.len())) {
                            let p = *rng.choose(&prev);
                            let e = g.connect(p, c);
                            edges_of.push(vec![p, c, e]);
                        }
                    }
                }
                prev = cur;
            }
            // State machines: producers emit on all out-edges.
            let outs = g.out_edges();
            let ins = g.in_edges();
            for i in 0..g.nodes.len() {
                let states = rng.range(1, 4) as u64;
                let mut st = State::new(rng.range(1, 5) as u64);
                for &e in &outs[i] {
                    st = st.emitting(e, 8);
                }
                for &e in &ins[i] {
                    st = st.needing(e, 8);
                }
                let mut m = StateMachine::new();
                // Consumers must not need more than producers emit:
                // equalize state counts via min with producer counts later;
                // simplest: same count everywhere.
                m.repeat(states, st);
                g.nodes[i].sm = m;
            }
            // Equalize: set every node's state count to the min over graph
            // so flow conservation holds.
            let minc = g.nodes.iter().map(|x| x.sm.num_states()).min().unwrap();
            for node in &mut g.nodes {
                let proto = node.sm.phases[0].proto.clone();
                let mut m = StateMachine::new();
                m.repeat(minc, proto);
                node.sm = m;
            }
            if g.validate().is_err() {
                continue;
            }
            let ev = simulate(&g, 0.0, false).unwrap().cycles;
            let pc = simulate_percycle(&g);
            assert_eq!(ev, pc, "case {case}: event={ev} percycle={pc}");
        }
    }
}
