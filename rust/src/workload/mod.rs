//! Workload-driven serving simulation: arrival processes, a bounded
//! admission queue, and tail-latency statistics over the fine simulator's
//! fill/steady-period model.
//!
//! The fine mode answers "how fast is one (batched) inference"; serving
//! heavy traffic is governed by *tail latency under bursty arrivals*,
//! which depends on the arrival process and queueing, not just the
//! service time. [`simulate_workload`] is a deterministic discrete-event
//! simulation of that regime, O(events) in the number of requests:
//!
//! - The design is abstracted to two numbers taken from a [`FineReport`]:
//!   the steady-state **initiation interval** (`1000 / steady_fps()` ms —
//!   a new inference can start this often once the pipeline is full) and
//!   the **service latency** per inference
//!   (`latency_per_inference_ms()`). No per-request fine-sim re-run.
//! - Arrivals come from an [`ArrivalProcess`]: deterministic `Uniform`
//!   spacing, `Poisson` exponential gaps, a two-state Markov-modulated
//!   `MarkovBurst` (both via the seeded in-tree PRNG — same seed, same
//!   byte-identical report), or a literal `Trace` of timestamps loaded
//!   from a JSON file.
//! - A bounded admission queue (depth [`Workload::queue_depth`]) either
//!   **drops** excess arrivals or **blocks** them until a slot frees
//!   ([`QueuePolicy`]).
//!
//! The resulting [`WorkloadReport`] carries p50/p95/p99/mean/max latency,
//! achieved QPS, the queue-depth histogram, drop/block counts, server
//! utilization and per-stage occupancy under load — the inputs the
//! builder's `ServeSlo` objective and the occupancy-fed `BufferResize`
//! move optimize against.

use anyhow::{bail, Context, Result};

use crate::graph::Graph;
use crate::predictor::{simulate_batched, FineReport};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

/// Batch used when probing a design's steady state for serving: deep
/// enough that `steady_fps()` reflects pipeline overlap rather than the
/// single-shot latency, small enough to stay cheap inside the DSE loop.
pub const SERVE_PROBE_BATCH: usize = 8;

/// Request count used when the stage-2 move engine scores a candidate
/// under the `ServeSlo` objective — enough events for a stable p99 at a
/// cost far below one fine simulation.
pub const DSE_REQUESTS: usize = 2_000;

/// Default request count for user-facing runs (CLI, JSONL requests,
/// result.json's `"workload"` section).
pub const DEFAULT_REQUESTS: usize = 10_000;

/// Default admission-queue depth when a config does not name one.
pub const DEFAULT_QUEUE_DEPTH: usize = 64;

/// In a `MarkovBurst` arrival process the burst state emits at
/// `BURST_FACTOR ×` the nominal rate and the calm state at
/// `1/BURST_FACTOR ×`; state runs last [`BURST_RUN`] arrivals in
/// expectation.
pub const BURST_FACTOR: f64 = 4.0;
/// Expected arrivals per Markov state run (switch probability 1/16).
pub const BURST_RUN: f64 = 16.0;

/// Synthetic arrival-process kinds — fieldless so the builder's
/// `Objective::ServeSlo` stays `Copy + Eq`. `Trace` arrivals (which carry
/// their timestamps) exist only at the [`ArrivalProcess`] level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArrivalKind {
    /// Deterministic spacing at exactly `1000/qps` ms.
    Uniform,
    /// Exponential inter-arrival gaps with mean `1000/qps` ms.
    Poisson,
    /// Two-state Markov-modulated Poisson: bursts at `BURST_FACTOR × qps`
    /// alternate with calm at `qps / BURST_FACTOR`.
    Burst,
}

impl ArrivalKind {
    /// Strict config-schema spelling (`"arrival"` key).
    pub fn as_str(&self) -> &'static str {
        match self {
            ArrivalKind::Uniform => "uniform",
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Burst => "burst",
        }
    }

    /// Inverse of [`as_str`](Self::as_str); errors name the valid set.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "uniform" => Ok(ArrivalKind::Uniform),
            "poisson" => Ok(ArrivalKind::Poisson),
            "burst" => Ok(ArrivalKind::Burst),
            other => bail!("unknown arrival kind {other:?} (expected uniform|poisson|burst)"),
        }
    }
}

/// What happens when a request arrives to a full admission queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QueuePolicy {
    /// The request is discarded and counted in `WorkloadReport::dropped`.
    Drop,
    /// The client waits for a slot; the wait counts toward its latency
    /// and the request is counted in `WorkloadReport::blocked`.
    Block,
}

impl QueuePolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            QueuePolicy::Drop => "drop",
            QueuePolicy::Block => "block",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "drop" => Ok(QueuePolicy::Drop),
            "block" => Ok(QueuePolicy::Block),
            other => bail!("unknown queue policy {other:?} (expected drop|block)"),
        }
    }
}

/// The `Copy + Eq` workload description embedded in
/// `Objective::ServeSlo` and the strict `"workload"` config object.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WorkloadSpec {
    pub arrival: ArrivalKind,
    /// Offered load in requests/s (≥ 1).
    pub qps: u64,
    /// PRNG seed — same seed, byte-identical [`WorkloadReport`].
    pub seed: u64,
    /// Admission-queue bound (≥ 1).
    pub queue_depth: usize,
    pub policy: QueuePolicy,
}

impl WorkloadSpec {
    /// Poisson arrivals at `qps` with the default seed/queue/policy —
    /// the shape `--qps N` constructs before `--arrival`/`--seed`/
    /// `--queue-depth` override fields.
    pub fn poisson(qps: u64) -> Self {
        WorkloadSpec {
            arrival: ArrivalKind::Poisson,
            qps,
            seed: 0,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            policy: QueuePolicy::Drop,
        }
    }

    /// Structural validity: zero-rate traffic or a zero-slot queue is a
    /// spec error, not a simulation outcome.
    pub fn validate(&self) -> Result<()> {
        if self.qps == 0 {
            bail!("workload qps must be >= 1");
        }
        if self.queue_depth == 0 {
            bail!("workload queue_depth must be >= 1");
        }
        Ok(())
    }

    /// Expand into a runnable [`Workload`] over `requests` arrivals.
    pub fn workload(&self, requests: usize) -> Workload {
        Workload {
            arrival: ArrivalProcess::from(self.arrival),
            qps: self.qps,
            seed: self.seed,
            queue_depth: self.queue_depth,
            policy: self.policy,
            requests,
        }
    }
}

/// A full arrival process, including literal traces. Synthetic kinds are
/// generated lazily from (`qps`, `seed`); a `Trace` carries its
/// timestamps (milliseconds, sorted ascending).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    Uniform,
    Poisson,
    MarkovBurst,
    Trace(Vec<f64>),
}

impl From<ArrivalKind> for ArrivalProcess {
    fn from(k: ArrivalKind) -> Self {
        match k {
            ArrivalKind::Uniform => ArrivalProcess::Uniform,
            ArrivalKind::Poisson => ArrivalProcess::Poisson,
            ArrivalKind::Burst => ArrivalProcess::MarkovBurst,
        }
    }
}

/// A runnable workload: arrival process + load + queue discipline +
/// horizon. Built from a [`WorkloadSpec`] (synthetic) or a trace file.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    pub arrival: ArrivalProcess,
    pub qps: u64,
    pub seed: u64,
    pub queue_depth: usize,
    pub policy: QueuePolicy,
    /// Number of requests for synthetic processes (a `Trace` brings its
    /// own length).
    pub requests: usize,
}

impl Workload {
    /// A workload replaying `timestamps_ms` (sorted on construction).
    pub fn from_trace(mut timestamps_ms: Vec<f64>, queue_depth: usize) -> Result<Self> {
        if timestamps_ms.is_empty() {
            bail!("workload trace is empty");
        }
        for &t in &timestamps_ms {
            if !t.is_finite() || t < 0.0 {
                bail!("workload trace timestamp {t} is not a finite non-negative ms value");
            }
        }
        timestamps_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite timestamps"));
        let requests = timestamps_ms.len();
        Ok(Workload {
            arrival: ArrivalProcess::Trace(timestamps_ms),
            qps: 0,
            seed: 0,
            queue_depth,
            policy: QueuePolicy::Drop,
            requests,
        })
    }

    /// Arrival timestamps in ms, deterministic in (`arrival`, `qps`,
    /// `seed`, `requests`).
    pub fn arrival_times(&self) -> Result<Vec<f64>> {
        if let ArrivalProcess::Trace(ts) = &self.arrival {
            return Ok(ts.clone());
        }
        if self.qps == 0 {
            bail!("workload qps must be >= 1 for synthetic arrivals");
        }
        if self.requests == 0 {
            bail!("workload must carry at least one request");
        }
        let gap = 1000.0 / self.qps as f64;
        let mut times = Vec::with_capacity(self.requests);
        match &self.arrival {
            ArrivalProcess::Uniform => {
                for i in 0..self.requests {
                    times.push(i as f64 * gap);
                }
            }
            ArrivalProcess::Poisson => {
                let mut rng = Rng::new(self.seed).fork("workload.poisson");
                let mut t = 0.0;
                for _ in 0..self.requests {
                    times.push(t);
                    t += exp_gap(&mut rng, gap);
                }
            }
            ArrivalProcess::MarkovBurst => {
                let mut rng = Rng::new(self.seed).fork("workload.burst");
                let mut bursting = rng.bool(0.5);
                let mut t = 0.0;
                for _ in 0..self.requests {
                    times.push(t);
                    let mean = if bursting { gap / BURST_FACTOR } else { gap * BURST_FACTOR };
                    t += exp_gap(&mut rng, mean);
                    if rng.bool(1.0 / BURST_RUN) {
                        bursting = !bursting;
                    }
                }
            }
            ArrivalProcess::Trace(_) => unreachable!("handled above"),
        }
        Ok(times)
    }
}

/// Exponential gap with the given mean (ms). `1 - f64()` keeps the log
/// argument in (0, 1].
fn exp_gap(rng: &mut Rng, mean_ms: f64) -> f64 {
    -(1.0 - rng.f64()).ln() * mean_ms
}

/// Load a `Trace` workload from a JSON file: either a bare array of
/// millisecond timestamps or `{"timestamps_ms": [...]}`.
pub fn load_trace(path: &std::path::Path) -> Result<Vec<f64>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading workload trace {}", path.display()))?;
    let json = Json::parse(&text)
        .with_context(|| format!("parsing workload trace {}", path.display()))?;
    let arr = json
        .as_arr()
        .or_else(|| json.get("timestamps_ms").and_then(|v| v.as_arr()))
        .with_context(|| {
            format!(
                "workload trace {} must be a JSON array of ms timestamps \
                 or an object with \"timestamps_ms\"",
                path.display()
            )
        })?;
    let mut ts = Vec::with_capacity(arr.len());
    for (i, v) in arr.iter().enumerate() {
        let t = v
            .as_f64()
            .with_context(|| format!("trace entry {i} is not a number"))?;
        ts.push(t);
    }
    Ok(ts)
}

/// Everything the serving simulation observed. Deterministic in
/// (`FineReport`, `Workload`): same inputs, byte-identical report.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadReport {
    /// Arrivals offered (trace length or `Workload::requests`).
    pub requests: usize,
    /// Requests that completed service.
    pub completed: usize,
    /// Requests discarded by the `Drop` policy.
    pub dropped: usize,
    /// Requests that had to wait for queue room under `Block`.
    pub blocked: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// Completions per second over the simulated horizon.
    pub achieved_qps: f64,
    /// Offered rate (nominal `qps`, or the trace's empirical rate).
    pub offered_qps: f64,
    /// First arrival to last completion, ms.
    pub horizon_ms: f64,
    /// `queue_hist[d]` = arrivals that found `d` requests queued ahead of
    /// them (last bin saturates at `queue_depth`).
    pub queue_hist: Vec<u64>,
    pub max_queue_depth: usize,
    /// `dropped / requests`.
    pub drop_rate: f64,
    /// Fraction of the horizon the design was initiating inferences.
    pub utilization: f64,
    /// Service latency per inference fed to the queue model
    /// (`FineReport::latency_per_inference_ms`).
    pub service_ms: f64,
    /// Steady-state initiation interval (`1000 / steady_fps`).
    pub period_ms: f64,
    /// Per-stage pipeline occupancy *under this load*: the fine sim's
    /// per-node occupancy scaled by server utilization — the signal the
    /// `BufferResize` move reads.
    pub occupancy: Vec<f64>,
}

impl WorkloadReport {
    /// The tail statistic `Spec::max_p99_ms` bounds.
    pub fn p99_ms(&self) -> f64 {
        self.p99_ms
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("requests", (self.requests as u64).into()),
            ("completed", (self.completed as u64).into()),
            ("dropped", (self.dropped as u64).into()),
            ("blocked", (self.blocked as u64).into()),
            ("p50_ms", self.p50_ms.into()),
            ("p95_ms", self.p95_ms.into()),
            ("p99_ms", self.p99_ms.into()),
            ("mean_ms", self.mean_ms.into()),
            ("max_ms", self.max_ms.into()),
            ("achieved_qps", self.achieved_qps.into()),
            ("offered_qps", self.offered_qps.into()),
            ("horizon_ms", self.horizon_ms.into()),
            ("queue_hist", Json::Arr(self.queue_hist.iter().map(|&c| c.into()).collect())),
            ("max_queue_depth", (self.max_queue_depth as u64).into()),
            ("drop_rate", self.drop_rate.into()),
            ("utilization", self.utilization.into()),
            ("service_ms", self.service_ms.into()),
            ("period_ms", self.period_ms.into()),
            ("occupancy", Json::Arr(self.occupancy.iter().map(|&o| o.into()).collect())),
        ])
    }
}

/// Sorted-sample percentile with deterministic nearest-rank-style
/// indexing (`p` in [0, 100]).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Serve `workload` on the design summarized by `fine`.
///
/// The design acts as a pipelined server: it *initiates* at most one
/// inference per `period_ms = 1000 / fine.steady_fps()` and each
/// initiated inference *completes* `service_ms =
/// fine.latency_per_inference_ms()` later, so
/// `start_i = max(arrival_i, start_{i-1} + period_ms)` and
/// `latency_i = start_i + service_ms - arrival_i`. Arrivals that find
/// `queue_depth` requests already waiting are dropped or blocked per
/// [`QueuePolicy`]. O(requests) time, deterministic.
pub fn simulate_workload(fine: &FineReport, workload: &Workload) -> Result<WorkloadReport> {
    let _span = crate::obs::span("workload.simulate");
    let service_ms = fine.latency_per_inference_ms();
    let steady_fps = fine.steady_fps();
    if steady_fps <= 0.0 || !service_ms.is_finite() || service_ms <= 0.0 {
        bail!(
            "design has no sustainable service rate (steady_fps {steady_fps}, \
             service {service_ms} ms) — cannot serve a workload"
        );
    }
    if workload.queue_depth == 0 {
        bail!("workload queue_depth must be >= 1");
    }
    let period_ms = 1000.0 / steady_fps;
    let arrivals = workload.arrival_times()?;
    let requests = arrivals.len();

    // Admitted-request start times are monotone nondecreasing, so the
    // queue depth seen by an arrival is `admitted - started` with a
    // single pointer advancing over `starts` — O(requests) total.
    let mut starts: Vec<f64> = Vec::with_capacity(requests);
    let mut started = 0usize; // starts[..started] have begun service
    let mut latencies: Vec<f64> = Vec::with_capacity(requests);
    let mut queue_hist = vec![0u64; workload.queue_depth + 1];
    let mut max_queue_depth = 0usize;
    let mut dropped = 0usize;
    let mut blocked = 0usize;
    let mut last_complete: f64 = 0.0;

    for &arrival in &arrivals {
        while started < starts.len() && starts[started] <= arrival {
            started += 1;
        }
        let depth = starts.len() - started;
        queue_hist[depth.min(workload.queue_depth)] += 1;
        max_queue_depth = max_queue_depth.max(depth);

        let mut effective_arrival = arrival;
        if depth >= workload.queue_depth {
            match workload.policy {
                QueuePolicy::Drop => {
                    dropped += 1;
                    continue;
                }
                QueuePolicy::Block => {
                    // Wait until the request `queue_depth` places ahead
                    // starts, freeing one slot.
                    blocked += 1;
                    let room_at = starts[starts.len() - workload.queue_depth];
                    effective_arrival = effective_arrival.max(room_at);
                }
            }
        }
        let start = match starts.last() {
            Some(&prev) => effective_arrival.max(prev + period_ms),
            None => effective_arrival,
        };
        starts.push(start);
        let complete = start + service_ms;
        latencies.push(complete - arrival);
        last_complete = last_complete.max(complete);
    }

    let completed = latencies.len();
    let first_arrival = arrivals.first().copied().unwrap_or(0.0);
    let last_arrival = arrivals.last().copied().unwrap_or(0.0);
    let horizon_ms = (last_complete.max(last_arrival) - first_arrival).max(f64::MIN_POSITIVE);
    let achieved_qps = completed as f64 * 1000.0 / horizon_ms;
    let offered_qps = match &workload.arrival {
        ArrivalProcess::Trace(_) => requests as f64 * 1000.0 / horizon_ms,
        _ => workload.qps as f64,
    };
    let utilization = (completed as f64 * period_ms / horizon_ms).min(1.0);
    let occupancy: Vec<f64> =
        fine.per_node.iter().map(|n| n.occupancy * utilization).collect();

    let mean_ms = if completed == 0 {
        0.0
    } else {
        latencies.iter().sum::<f64>() / completed as f64
    };
    let mut sorted = latencies;
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let report = WorkloadReport {
        requests,
        completed,
        dropped,
        blocked,
        p50_ms: percentile(&sorted, 50.0),
        p95_ms: percentile(&sorted, 95.0),
        p99_ms: percentile(&sorted, 99.0),
        mean_ms,
        max_ms: sorted.last().copied().unwrap_or(0.0),
        achieved_qps,
        offered_qps,
        horizon_ms,
        queue_hist,
        max_queue_depth,
        drop_rate: dropped as f64 / requests.max(1) as f64,
        utilization,
        service_ms,
        period_ms,
        occupancy,
    };
    if crate::obs::enabled() {
        crate::obs::metrics::counter("workload.requests", report.requests as u64);
        crate::obs::metrics::counter("workload.completed", report.completed as u64);
        crate::obs::metrics::counter("workload.dropped", report.dropped as u64);
        crate::obs::metrics::counter("workload.blocked", report.blocked as u64);
        crate::obs::metrics::record("workload.p99_us", (report.p99_ms * 1000.0) as u64);
        crate::obs::metrics::record(
            "workload.queue_depth_max",
            report.max_queue_depth as u64,
        );
    }
    Ok(report)
}

/// Convenience entry over a design graph: probe the steady state with a
/// [`SERVE_PROBE_BATCH`]-deep batched fine simulation, then serve the
/// workload on that report.
pub fn simulate_workload_graph(
    g: &Graph,
    leakage_mw: f64,
    workload: &Workload,
) -> Result<WorkloadReport> {
    let fine = simulate_batched(g, SERVE_PROBE_BATCH, leakage_mw, false)?;
    simulate_workload(&fine, workload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NodeId;
    use crate::predictor::NodeSim;

    /// A synthetic steady-state report: 8 inferences, 10 ms makespan,
    /// period 100 cycles of 1000 → service 1.25 ms, period 1 ms.
    fn probe_report() -> FineReport {
        FineReport {
            cycles: 1000,
            latency_ms: 10.0,
            energy_pj: 1.0,
            per_node: vec![
                NodeSim { occupancy: 0.9, ..Default::default() },
                NodeSim { occupancy: 0.4, ..Default::default() },
            ],
            bottleneck: NodeId::default(),
            trace: Vec::new(),
            batch: 8,
            fill_cycles: 300,
            steady_period_cycles: 100,
        }
    }

    fn spec(qps: u64, arrival: ArrivalKind) -> WorkloadSpec {
        WorkloadSpec { arrival, ..WorkloadSpec::poisson(qps) }
    }

    #[test]
    fn uniform_low_qps_p99_is_service_latency() {
        let fine = probe_report();
        // steady_fps = 100 per period-ms → 1000 fps; offer 10 qps.
        let w = spec(10, ArrivalKind::Uniform).workload(500);
        let r = simulate_workload(&fine, &w).unwrap();
        assert_eq!(r.completed, 500);
        assert_eq!(r.dropped + r.blocked, 0);
        assert!((r.p99_ms - fine.latency_per_inference_ms()).abs() < 1e-9);
        assert!((r.p50_ms - r.p99_ms).abs() < 1e-9, "no queueing at low load");
        assert_eq!(r.max_queue_depth, 0);
        assert_eq!(r.queue_hist[0], 500);
    }

    #[test]
    fn overload_drops_with_drop_policy_and_blocks_with_block_policy() {
        let fine = probe_report(); // sustains 1000 qps
        let mut w = spec(4000, ArrivalKind::Uniform).workload(2000);
        w.queue_depth = 4;
        let r = simulate_workload(&fine, &w).unwrap();
        assert!(r.dropped > 0, "overload must drop under Drop policy");
        assert!(r.achieved_qps < 4000.0 * 0.9);
        assert!(r.drop_rate > 0.0);

        w.policy = QueuePolicy::Block;
        let rb = simulate_workload(&fine, &w).unwrap();
        assert_eq!(rb.dropped, 0);
        assert!(rb.blocked > 0, "overload must block under Block policy");
        assert!(rb.p99_ms > r.p99_ms, "blocking waits show up in the tail");
        assert_eq!(rb.completed, rb.requests);
    }

    #[test]
    fn seeded_poisson_and_burst_are_deterministic() {
        let fine = probe_report();
        for kind in [ArrivalKind::Poisson, ArrivalKind::Burst] {
            let w = WorkloadSpec { seed: 42, ..spec(800, kind) }.workload(3000);
            let a = simulate_workload(&fine, &w).unwrap();
            let b = simulate_workload(&fine, &w).unwrap();
            assert_eq!(a, b, "same seed must be byte-identical ({kind:?})");
            let w2 = WorkloadSpec { seed: 43, ..spec(800, kind) }.workload(3000);
            let c = simulate_workload(&fine, &w2).unwrap();
            assert_ne!(a, c, "different seed must differ ({kind:?})");
        }
    }

    #[test]
    fn burst_arrivals_have_heavier_tail_than_uniform() {
        let fine = probe_report();
        let near = 900; // near saturation (sustains 1000)
        let uni = simulate_workload(&fine, &spec(near, ArrivalKind::Uniform).workload(5000))
            .unwrap();
        let burst = simulate_workload(&fine, &spec(near, ArrivalKind::Burst).workload(5000))
            .unwrap();
        assert!(
            burst.p99_ms > uni.p99_ms,
            "burst p99 {} must exceed uniform p99 {}",
            burst.p99_ms,
            uni.p99_ms
        );
    }

    #[test]
    fn trace_workload_replays_timestamps() {
        let fine = probe_report();
        let w = Workload::from_trace(vec![5.0, 0.0, 2.0, 100.0], 8).unwrap();
        let r = simulate_workload(&fine, &w).unwrap();
        assert_eq!(r.requests, 4);
        assert_eq!(r.completed, 4);
        assert!(r.offered_qps > 0.0);
    }

    #[test]
    fn occupancy_scales_with_utilization() {
        let fine = probe_report();
        let light = simulate_workload(&fine, &spec(10, ArrivalKind::Uniform).workload(500))
            .unwrap();
        let heavy = simulate_workload(&fine, &spec(990, ArrivalKind::Uniform).workload(500))
            .unwrap();
        assert_eq!(light.occupancy.len(), 2);
        assert!(light.utilization < heavy.utilization);
        assert!(light.occupancy[0] < heavy.occupancy[0]);
        assert!(heavy.occupancy[0] <= fine.per_node[0].occupancy + 1e-12);
    }

    #[test]
    fn zero_rate_designs_and_empty_traces_are_errors() {
        let mut fine = probe_report();
        fine.steady_period_cycles = 0;
        let w = spec(10, ArrivalKind::Uniform).workload(10);
        assert!(simulate_workload(&fine, &w).is_err());
        assert!(Workload::from_trace(Vec::new(), 8).is_err());
        assert!(Workload::from_trace(vec![f64::NAN], 8).is_err());
        assert!(WorkloadSpec { qps: 0, ..WorkloadSpec::poisson(1) }.validate().is_err());
        assert!(
            WorkloadSpec { queue_depth: 0, ..WorkloadSpec::poisson(1) }.validate().is_err()
        );
    }

    #[test]
    fn arrival_kind_and_policy_round_trip_strings() {
        for k in [ArrivalKind::Uniform, ArrivalKind::Poisson, ArrivalKind::Burst] {
            assert_eq!(ArrivalKind::parse(k.as_str()).unwrap(), k);
        }
        for p in [QueuePolicy::Drop, QueuePolicy::Block] {
            assert_eq!(QueuePolicy::parse(p.as_str()).unwrap(), p);
        }
        assert!(ArrivalKind::parse("bursty").is_err());
        assert!(QueuePolicy::parse("shed").is_err());
    }

    #[test]
    fn report_json_carries_every_field() {
        let fine = probe_report();
        let r = simulate_workload(&fine, &spec(700, ArrivalKind::Poisson).workload(1000))
            .unwrap();
        let j = r.to_json();
        for key in [
            "requests", "completed", "dropped", "blocked", "p50_ms", "p95_ms", "p99_ms",
            "mean_ms", "max_ms", "achieved_qps", "offered_qps", "horizon_ms", "queue_hist",
            "max_queue_depth", "drop_rate", "utilization", "service_ms", "period_ms",
            "occupancy",
        ] {
            assert!(j.get(key).is_some(), "report JSON missing {key}");
        }
        assert_eq!(j.get("requests").and_then(|v| v.as_u64()), Some(1000));
    }
}
