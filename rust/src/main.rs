//! AutoDNNchip CLI — the L3 leader entrypoint.
//!
//! ```text
//! autodnnchip list-models
//! autodnnchip predict  --model SK --template hetero_dw_pw --tech ultra96
//!                      [--batch N]
//!                      [--qps N | --workload FILE] [--arrival uniform|poisson|burst]
//!                      [--seed N] [--queue-depth N] [--policy drop|block]
//!                      [--requests N]
//! autodnnchip build    --model SK [--backend fpga|asic] [--rtl-out DIR]
//!                      [--moves legacy|full] [--cache-dir DIR]
//!                      [--dse exhaustive|surrogate] [--grid standard|dense]
//!                      [--batch N]
//!                      [--qps N | --workload FILE] [--max-p99-ms MS]
//! autodnnchip build    --model-json examples/models/tinyconv.json
//! autodnnchip build    --config cfg.json
//! autodnnchip sweep    --model SK [--backend fpga|asic] [--n2 N]
//!                      [--cache-dir DIR] [--out DIR] [--workers N]
//!                      [--dse exhaustive|surrogate] [--grid standard|dense]
//!                      [--dump-training FILE]
//!                      [--qps N | --workload FILE] [--max-p99-ms MS]
//! autodnnchip serve    --requests file.jsonl [--out DIR] [--workers N]
//!                      [--verbose] [--cache-dir DIR]
//! autodnnchip exp      <fig7|fig8|fig9|fig10|table6|table7|table8|
//!                       fig11|fig12|fig13|fig14|fig15|all> [--seed N]
//! autodnnchip validate [--artifacts DIR]
//! ```
//!
//! `--cache-dir DIR` makes the DSE cache persistent: shards found in DIR
//! are loaded before the sweep (stale/corrupt ones skipped with a
//! warning) and the cache is saved back afterwards, so a rerun — even
//! after the process died — starts warm.
//!
//! `--dse surrogate` prunes the stage-1 sweep with a ridge surrogate
//! fitted on the DSE cache (falls back to exhaustive until the cache is
//! warm enough); `--grid dense` sweeps the denser grid tier sized for
//! surrogate runs. `sweep --dump-training FILE` serializes the featurized
//! (features, objective) training rows plus stage-2 move accept/reject
//! counters for offline surrogate studies.
//!
//! `--batch N` switches a run to steady-state throughput semantics: the
//! fine simulator models N inferences in flight (`predict`'s fine column
//! becomes the batched makespan) and `build`/`sweep` optimize the
//! `throughput` objective at that depth instead of single-shot latency.
//!
//! `--qps N` (or `--workload FILE`, a JSON timestamp trace) switches a run
//! to serving semantics: `predict` replays the workload through the
//! discrete-event serving simulator and prints tail latency / drop-rate /
//! queue statistics, while `build`/`sweep` optimize the `serve_slo`
//! objective — meet `--max-p99-ms MS` (p99 tail under load) at minimum
//! energy. `--arrival`, `--seed`, `--queue-depth` and `--policy` shape the
//! synthetic arrival process; `--batch` and `--qps` are mutually
//! exclusive.
//!
//! `predict` and `build` route through the `api::Engine` facade — the CLI
//! is one consumer of the same typed request/response surface the JSONL
//! serving mode (`serve`) exposes.
//!
//! Every command additionally accepts `--trace-out FILE` (Chrome
//! `trace_event` JSON, loadable in Perfetto / chrome://tracing) and
//! `--metrics-out FILE` (a metric-registry snapshot); either flag switches
//! instrumentation on for the whole process. `serve` records telemetry
//! unconditionally so JSONL `{"type":"stats"}` requests always have data.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};
use autodnnchip::api::{self, Engine, PredictRequest, Request, Response, SimulateWorkloadRequest};
use autodnnchip::builder::{surrogate, Objective, Spec};
use autodnnchip::coordinator::{DseChoice, GridChoice, MoveSetChoice, RunConfig};
use autodnnchip::dnn::zoo;
use autodnnchip::util::cli::Args;
use autodnnchip::util::table::{f, Table};
use autodnnchip::workload::{self, ArrivalKind, QueuePolicy, WorkloadSpec};
use autodnnchip::{experiments, obs, runtime};

/// Where the `--trace-out`/`--metrics-out` telemetry goes. Every command
/// accepts both flags; either one switches instrumentation on for the
/// whole process ([`obs::set_enabled`]).
struct ObsOutputs {
    trace_out: Option<String>,
    metrics_out: Option<String>,
}

fn obs_outputs(args: &Args) -> ObsOutputs {
    let out = ObsOutputs {
        trace_out: args.flag("trace-out").map(|s| s.to_string()),
        metrics_out: args.flag("metrics-out").map(|s| s.to_string()),
    };
    if out.trace_out.is_some() || out.metrics_out.is_some() {
        obs::set_enabled(true);
    }
    if out.trace_out.is_some() {
        obs::install_trace_sink();
    }
    out
}

impl ObsOutputs {
    /// Write whatever was requested (called after the command body, even a
    /// failing one — a failing build's trace is the one worth reading).
    fn finish(&self) -> Result<()> {
        if let Some(p) = &self.metrics_out {
            obs::write_metrics(Path::new(p)).with_context(|| format!("writing '{p}'"))?;
            eprintln!("wrote {p}");
        }
        if let Some(p) = &self.trace_out {
            obs::write_chrome_trace(Path::new(p)).with_context(|| format!("writing '{p}'"))?;
            eprintln!("wrote {p} (load in Perfetto / chrome://tracing)");
        }
        Ok(())
    }
}

fn main() -> ExitCode {
    let args = Args::from_env();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let telemetry = obs_outputs(args);
    let result = run_command(args);
    // Flush telemetry even when the command failed; but never let a flush
    // error mask the command's own error.
    match telemetry.finish() {
        Err(e) if result.is_ok() => Err(e),
        Err(e) => {
            eprintln!("warning: {e:#}");
            result
        }
        Ok(()) => result,
    }
}

/// Flags every command accepts (handled in [`dispatch`], before the
/// command body runs).
const OBS_FLAGS: [&str; 2] = ["trace-out", "metrics-out"];

/// The serving-workload flag family, registered on every command
/// (threaded into the run by predict/build/sweep, accepted as no-ops
/// elsewhere so scripted flag sets can be shared across commands).
const WORKLOAD_FLAGS: [&str; 6] = ["workload", "qps", "arrival", "seed", "queue-depth", "policy"];

/// `known` command flags plus the global observability flags, for
/// `warn_unknown_flags`.
fn with_obs_flags<'a>(known: &[&'a str]) -> Vec<&'a str> {
    known.iter().copied().chain(OBS_FLAGS).collect()
}

/// [`with_obs_flags`] plus the workload flag family — the allowlist every
/// command registers.
fn with_shared_flags<'a>(known: &[&'a str]) -> Vec<&'a str> {
    known.iter().copied().chain(OBS_FLAGS).chain(WORKLOAD_FLAGS).collect()
}

fn run_command(args: &Args) -> Result<()> {
    match args.subcommand.first().map(|s| s.as_str()) {
        Some("list-models") => {
            args.warn_unknown_flags(&with_shared_flags(&["batch"]));
            let mut t = Table::new("model zoo", &["name", "layers", "params (M)", "MACs (M)"]);
            for name in zoo::all_names() {
                let m = zoo::by_name(&name).unwrap();
                let s = m.stats()?;
                t.row(vec![
                    name,
                    m.layers.len().to_string(),
                    f(s.total_params as f64 / 1e6, 3),
                    f(s.total_macs as f64 / 1e6, 1),
                ]);
            }
            print!("{}", t.render());
            Ok(())
        }
        Some("predict") => cmd_predict(args),
        Some("build") => cmd_build(args),
        Some("sweep") => cmd_sweep(args),
        Some("serve") => cmd_serve(args),
        Some("exp") => cmd_exp(args),
        Some("validate") => cmd_validate(args),
        Some(other) => bail!("unknown command '{other}'"),
        None => {
            eprintln!(
                "usage: autodnnchip <list-models|predict|build|sweep|serve|exp|validate> [flags]\n\
                 see `rust/src/main.rs` docs for details"
            );
            Ok(())
        }
    }
}

/// A numeric flag where an unparsable value warns and falls back to the
/// default, instead of silently no-opping.
fn numeric_flag<T: std::str::FromStr>(args: &Args, name: &str) -> Option<T> {
    args.flag(name).and_then(|s| match s.parse() {
        Ok(v) => Some(v),
        Err(_) => {
            eprintln!("warning: ignoring unparsable --{name} '{s}' (using the default)");
            None
        }
    })
}

/// Parse the shared `--dse` / `--grid` flags (build and sweep).
fn dse_flag(args: &Args) -> Result<Option<DseChoice>> {
    match args.flag("dse") {
        None => Ok(None),
        Some("exhaustive") => Ok(Some(DseChoice::Exhaustive)),
        Some("surrogate") => Ok(Some(DseChoice::Surrogate)),
        Some(other) => {
            bail!("unknown dse policy '{other}' (expected 'exhaustive' or 'surrogate')")
        }
    }
}

fn grid_flag(args: &Args) -> Result<GridChoice> {
    match args.flag("grid").unwrap_or("standard") {
        "standard" => Ok(GridChoice::Standard),
        "dense" => Ok(GridChoice::Dense),
        other => bail!("unknown grid tier '{other}' (expected 'standard' or 'dense')"),
    }
}

/// The shared `--batch N` flag (build and sweep): optimize steady-state
/// throughput with N inferences in flight instead of single-shot latency.
fn apply_batch_flag(args: &Args, spec: &mut Spec) -> Result<()> {
    if let Some(b) = numeric_flag::<usize>(args, "batch") {
        if b == 0 {
            bail!("--batch must be >= 1");
        }
        spec.objective = Objective::Throughput { batch: b };
    }
    Ok(())
}

/// The shared serving flags (build and sweep): `--qps N` — or `--workload
/// FILE`, summarized to the trace's mean arrival rate — switches the run
/// to the `serve_slo` objective, with `--arrival uniform|poisson|burst`,
/// `--seed S`, `--queue-depth D` and `--policy drop|block` shaping the
/// arrival process and `--max-p99-ms MS` setting the tail-latency bound.
fn apply_workload_flags(args: &Args, spec: &mut Spec) -> Result<()> {
    if let Some(bound) = numeric_flag::<f64>(args, "max-p99-ms") {
        spec.max_p99_ms = Some(bound);
    }
    let trace = args.flag("workload");
    let qps = match (trace, numeric_flag::<u64>(args, "qps")) {
        (Some(_), Some(_)) => bail!("--workload FILE and --qps N are mutually exclusive"),
        (Some(path), None) => trace_mean_qps(Path::new(path))?,
        (None, Some(0)) => bail!("--qps must be >= 1"),
        (None, Some(q)) => q,
        (None, None) => {
            for dependent in ["arrival", "queue-depth", "policy"] {
                if args.flag(dependent).is_some() {
                    bail!("--{dependent} requires --qps N (or --workload FILE)");
                }
            }
            spec.validate()?;
            return Ok(());
        }
    };
    if matches!(spec.objective, Objective::Throughput { .. }) {
        bail!("--batch and --qps/--workload are mutually exclusive (throughput vs serve_slo)");
    }
    let mut w = WorkloadSpec::poisson(qps);
    if let Some(kind) = args.flag("arrival") {
        w.arrival = ArrivalKind::parse(kind)?;
    }
    if let Some(seed) = numeric_flag::<u64>(args, "seed") {
        w.seed = seed;
    }
    if let Some(depth) = numeric_flag::<usize>(args, "queue-depth") {
        w.queue_depth = depth;
    }
    if let Some(policy) = args.flag("policy") {
        w.policy = QueuePolicy::parse(policy)?;
    }
    spec.objective = Objective::ServeSlo { workload: w };
    spec.validate()?;
    Ok(())
}

/// Mean offered rate of a timestamp trace, for runs whose `serve_slo`
/// workload must stay synthetic (the DSE's `WorkloadSpec` is `Copy`; the
/// literal trace replays only in `predict --workload` /
/// `simulate_workload` requests).
fn trace_mean_qps(path: &Path) -> Result<u64> {
    let ts = workload::load_trace(path)?;
    let (Some(first), Some(last)) = (ts.first(), ts.last()) else {
        bail!("workload trace {} is empty", path.display());
    };
    let span_ms = last - first;
    if ts.len() < 2 || span_ms <= 0.0 {
        bail!("workload trace {} needs >= 2 distinct timestamps to derive a rate", path.display());
    }
    let qps = ((ts.len() - 1) as f64 * 1000.0 / span_ms).round();
    Ok((qps as u64).max(1))
}

fn cmd_predict(args: &Args) -> Result<()> {
    let mut known = with_shared_flags(&[
        "model", "template", "tech", "unroll", "pipeline", "batch",
    ]);
    known.push("requests");
    args.warn_unknown_flags(&known);
    let req = PredictRequest {
        model: args.flag_or("model", "SK"),
        template: args.flag_or("template", "hetero_dw_pw"),
        tech: args.flag_or("tech", "ultra96"),
        unroll: numeric_flag(args, "unroll"),
        pipeline: numeric_flag(args, "pipeline"),
        batch: numeric_flag(args, "batch"),
    };
    if args.flag("qps").is_some() || args.flag("workload").is_some() {
        return predict_workload(args, req);
    }
    // Predict runs on the calling thread, so a single-worker engine avoids
    // spawning a machine-sized pool for the most common CLI command.
    let engine = Engine::builder().workers(1).build();
    let Response::Predict(p) = engine.submit(Request::Predict(req))? else {
        bail!("engine returned a non-predict response");
    };
    let mut t = Table::new(
        &format!("Chip Predictor — {} on {}", p.model, p.template),
        &["metric", "coarse", "fine"],
    );
    t.row(vec!["latency (ms)".into(), f(p.coarse_latency_ms, 3), f(p.fine_latency_ms, 3)]);
    t.row(vec!["energy (µJ)".into(), f(p.coarse_energy_uj, 1), f(p.fine_energy_pj / 1e6, 1)]);
    t.row(vec!["fps".into(), f(p.coarse_fps, 1), f(1000.0 / p.fine_latency_ms, 1)]);
    t.row(vec!["DSP".into(), p.dsp.to_string(), "-".into()]);
    t.row(vec!["BRAM18K".into(), p.bram18k.to_string(), "-".into()]);
    t.row(vec!["SRAM (KB)".into(), f(p.sram_kb, 1), "-".into()]);
    t.row(vec!["multipliers".into(), p.multipliers.to_string(), "-".into()]);
    print!("{}", t.render());
    Ok(())
}

/// `predict --qps N` / `predict --workload FILE`: serve the design point
/// under the requested arrival process and print the tail-latency report
/// (the CLI face of the `simulate_workload` JSONL request).
fn predict_workload(args: &Args, point: PredictRequest) -> Result<()> {
    let trace = args.flag("workload").map(|s| s.to_string());
    if trace.is_some() {
        for synthetic in ["qps", "arrival", "requests"] {
            if args.flag(synthetic).is_some() {
                bail!("--{synthetic} conflicts with --workload FILE (the trace brings its own arrivals)");
            }
        }
        if args.flag("seed").is_some() {
            bail!("--seed conflicts with --workload FILE (the trace brings its own arrivals)");
        }
    }
    let mut req = SimulateWorkloadRequest {
        point,
        qps: numeric_flag::<u64>(args, "qps"),
        trace,
        ..SimulateWorkloadRequest::poisson("SK", 1)
    };
    if let Some(kind) = args.flag("arrival") {
        req.arrival = ArrivalKind::parse(kind)?;
    }
    if let Some(seed) = numeric_flag::<u64>(args, "seed") {
        req.seed = seed;
    }
    if let Some(depth) = numeric_flag::<usize>(args, "queue-depth") {
        req.queue_depth = depth;
    }
    if let Some(policy) = args.flag("policy") {
        req.policy = QueuePolicy::parse(policy)?;
    }
    if let Some(n) = numeric_flag::<usize>(args, "requests") {
        req.requests = n;
    }
    let engine = Engine::builder().workers(1).build();
    let Response::SimulateWorkload(w) = engine.submit(Request::SimulateWorkload(req))? else {
        bail!("engine returned a non-workload response");
    };
    let r = &w.report;
    let mut t = Table::new(
        &format!("Workload simulation — {} on {}", w.model, w.template),
        &["metric", "value"],
    );
    t.row(vec!["requests".into(), r.requests.to_string()]);
    t.row(vec!["completed".into(), r.completed.to_string()]);
    t.row(vec!["dropped".into(), r.dropped.to_string()]);
    t.row(vec!["blocked".into(), r.blocked.to_string()]);
    t.row(vec!["p50 latency (ms)".into(), f(r.p50_ms, 3)]);
    t.row(vec!["p95 latency (ms)".into(), f(r.p95_ms, 3)]);
    t.row(vec!["p99 latency (ms)".into(), f(r.p99_ms, 3)]);
    t.row(vec!["offered qps".into(), f(r.offered_qps, 1)]);
    t.row(vec!["achieved qps".into(), f(r.achieved_qps, 1)]);
    t.row(vec!["drop rate".into(), f(r.drop_rate, 4)]);
    t.row(vec!["utilization".into(), f(r.utilization, 3)]);
    t.row(vec!["max queue depth".into(), r.max_queue_depth.to_string()]);
    print!("{}", t.render());
    Ok(())
}

fn cmd_build(args: &Args) -> Result<()> {
    args.warn_unknown_flags(&with_shared_flags(&[
        "config", "model", "model-json", "backend", "moves", "n2", "n-opt", "out", "rtl-out",
        "cache-dir", "dse", "grid", "batch", "max-p99-ms",
    ]));
    let cfg = if let Some(path) = args.flag("config") {
        // The config file carries the whole run; any other flag on the
        // line would be silently out-voted, so say so. The observability
        // flags are global (handled in `dispatch`), not part of the run
        // config, so they coexist with --config.
        let ignored = args.unknown_flags(&with_obs_flags(&["config"]));
        if !ignored.is_empty() {
            eprintln!(
                "warning: --config takes precedence; ignoring --{}",
                ignored.join(" --")
            );
        }
        RunConfig::from_file(path)?
    } else {
        let backend = args.flag_or("backend", "fpga");
        let mut spec = match backend.as_str() {
            "fpga" => Spec::ultra96_object_detection(),
            "asic" => Spec::asic_vision(),
            other => bail!("unknown backend '{other}'"),
        };
        apply_batch_flag(args, &mut spec)?;
        apply_workload_flags(args, &mut spec)?;
        let moves = match args.flag_or("moves", "full").as_str() {
            "legacy" => MoveSetChoice::Legacy,
            "full" => MoveSetChoice::Full,
            other => bail!("unknown move set '{other}' (expected 'legacy' or 'full')"),
        };
        RunConfig {
            model: args.flag_or("model", "SK"),
            // `--model-json path.json` imports a framework-export model
            // instead of naming a zoo entry.
            model_json: args.flag("model-json").map(|s| s.to_string()),
            spec,
            n2: numeric_flag(args, "n2").unwrap_or(4),
            n_opt: numeric_flag(args, "n-opt").unwrap_or(2),
            moves,
            dse: dse_flag(args)?,
            grid: grid_flag(args)?,
            out_dir: args.flag("out").map(|s| s.to_string()),
            rtl_out: args.flag("rtl-out").map(|s| s.to_string()),
            cache_dir: args.flag("cache-dir").map(|s| s.to_string()),
        }
    };
    let summary = Engine::builder().build().run(&cfg)?;
    println!("{}", summary.result_json.pretty());
    if summary.build.survivors.is_empty() {
        bail!("no design survived DSE + PnR");
    }
    Ok(())
}

/// Stage-1-only sweep: evaluate the coarse grid and print the sweep
/// response as pretty JSON. With `--cache-dir DIR` the sweep loads
/// persistent shards first and saves back after — the warm-restart path
/// the `restart` bench and the CI cache gates exercise. With
/// `--dump-training FILE` the featurized (features, objective) training
/// rows the surrogate fits on — every cache-labeled grid point — plus the
/// stage-2 move accept/reject counters are written to FILE after the
/// sweep.
fn cmd_sweep(args: &Args) -> Result<()> {
    args.warn_unknown_flags(&with_shared_flags(&[
        "model", "model-json", "backend", "n2", "cache-dir", "out", "workers", "dse", "grid",
        "dump-training", "batch", "max-p99-ms",
    ]));
    let backend = args.flag_or("backend", "fpga");
    let mut spec = match backend.as_str() {
        "fpga" => Spec::ultra96_object_detection(),
        "asic" => Spec::asic_vision(),
        other => bail!("unknown backend '{other}'"),
    };
    apply_batch_flag(args, &mut spec)?;
    apply_workload_flags(args, &mut spec)?;
    let cfg = RunConfig {
        model: args.flag_or("model", "SK"),
        model_json: args.flag("model-json").map(|s| s.to_string()),
        spec,
        n2: numeric_flag(args, "n2").unwrap_or(4),
        n_opt: 1,
        moves: MoveSetChoice::Full,
        dse: dse_flag(args)?,
        grid: grid_flag(args)?,
        out_dir: None,
        rtl_out: None,
        cache_dir: args.flag("cache-dir").map(|s| s.to_string()),
    };
    let mut builder = Engine::builder();
    if let Some(w) = numeric_flag::<usize>(args, "workers") {
        builder = builder.workers(w);
    }
    let engine = builder.build();
    let resp = engine.submit(Request::Sweep(api::SweepRequest(cfg.clone())))?;
    println!("{}", resp.to_json().pretty());
    if let Some(dir) = args.flag("out") {
        std::fs::create_dir_all(dir).with_context(|| format!("creating '{dir}'"))?;
        let out_path = Path::new(dir).join("sweep.json");
        std::fs::write(&out_path, resp.to_json().pretty())
            .with_context(|| format!("writing '{}'", out_path.display()))?;
        eprintln!("wrote {}", out_path.display());
    }
    if resp.is_error() {
        bail!("sweep failed");
    }
    if let Some(file) = args.flag("dump-training") {
        // The sweep above has just labeled (at least) this grid's points
        // in the cache, so the dump reflects the freshest predictions.
        let model = cfg.resolve_model()?;
        let grid = engine.grid_for(&cfg);
        let dump = surrogate::training_dump(
            &model,
            &cfg.spec,
            &grid,
            engine.cache(),
            &obs::metrics::global_snapshot(),
        )?;
        if let Some(parent) = Path::new(file).parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating '{}'", parent.display()))?;
        }
        std::fs::write(file, dump.pretty()).with_context(|| format!("writing '{file}'"))?;
        eprintln!("wrote {file}");
    }
    Ok(())
}

/// Batched serving mode: one JSON request per input line, one JSON
/// response per output line, in order; failing requests become in-place
/// `{"type":"error",...}` lines instead of aborting the stream. Response
/// lines stream: each is printed as soon as it and every line before it
/// have finished (see `api::serve`'s ordering contract), so one slow
/// build does not hold back the output of the cheap requests ahead of it.
fn cmd_serve(args: &Args) -> Result<()> {
    args.warn_unknown_flags(&with_shared_flags(&[
        "requests", "out", "workers", "verbose", "cache-dir", "batch",
    ]));
    let path = args.flag("requests").ok_or_else(|| {
        anyhow!(
            "usage: serve --requests file.jsonl [--out DIR] [--workers N] [--verbose] \
             [--cache-dir DIR]"
        )
    })?;
    // Serving mode always records telemetry, so a `{"type":"stats"}` line
    // has per-request-kind latency histograms, cache counters and stage
    // metrics to report without any extra flag.
    obs::set_enabled(true);
    let verbose = args.flag_bool("verbose");
    let mut builder = Engine::builder();
    if let Some(w) = numeric_flag::<usize>(args, "workers") {
        builder = builder.workers(w);
    }
    if let Some(dir) = args.flag("cache-dir") {
        builder = builder.cache_dir(dir);
    }
    let engine = builder.build();
    // Stream responses in request order as they finish; the same bytes the
    // old collect-then-print loop produced, just earlier.
    let mut print_line = |_i: usize, r: &Response, _ls: &api::LineStat| {
        println!("{}", r.to_json());
    };
    let outcome = api::serve_path_with(&engine, Path::new(path), Some(&mut print_line))?;
    if verbose {
        for (i, (ls, r)) in outcome.line_stats.iter().zip(&outcome.responses).enumerate() {
            let status = if r.is_error() { "error" } else { "ok" };
            eprintln!("request {}: {} {:.2} ms -> {status}", i + 1, ls.kind, ls.latency_ms);
        }
    }
    if let Some(dir) = args.flag("out") {
        std::fs::create_dir_all(dir).with_context(|| format!("creating '{dir}'"))?;
        let out_path = Path::new(dir).join("responses.jsonl");
        api::write_jsonl(&outcome.responses, &out_path)?;
        eprintln!("wrote {}", out_path.display());
    }
    eprintln!(
        "served {} request(s): {} ok, {} failed",
        outcome.responses.len(),
        outcome.ok,
        outcome.failed
    );
    if outcome.failed > 0 && outcome.ok == 0 {
        bail!("every request failed");
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    args.warn_unknown_flags(&with_shared_flags(&["seed", "results", "batch"]));
    let id = args
        .subcommand
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("usage: exp <id|all>"))?;
    let seed = numeric_flag::<u64>(args, "seed").unwrap_or(0xA070);
    let results = PathBuf::from(args.flag_or("results", "results"));
    let ids: Vec<&str> = if id == "all" { experiments::all_ids() } else { vec![id] };
    for id in ids {
        let t0 = std::time::Instant::now();
        let rep = experiments::run(id, seed).with_context(|| format!("experiment {id}"))?;
        rep.save(&results)?;
        println!("{}", rep.text);
        println!("[{} done in {:.1}s; results/{}.json written]\n", id, t0.elapsed().as_secs_f64(), id);
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    args.warn_unknown_flags(&with_shared_flags(&["artifacts", "batch"]));
    let dir = PathBuf::from(args.flag_or("artifacts", "artifacts"));
    let rt = runtime::Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    for name in rt.artifact_names() {
        let loaded = rt.load(&name)?;
        println!("  {name}: inputs {:?} → {} outputs", loaded.meta.input_shapes, loaded.meta.num_outputs);
    }
    println!("all artifacts compile under PJRT");
    Ok(())
}
