//! AutoDNNchip CLI — the L3 leader entrypoint.
//!
//! ```text
//! autodnnchip list-models
//! autodnnchip predict  --model SK --template hetero_dw_pw --tech ultra96
//! autodnnchip build    --model SK [--backend fpga|asic] [--rtl-out DIR]
//!                      [--moves legacy|full]
//! autodnnchip build    --model-json examples/models/tinyconv.json
//! autodnnchip build    --config cfg.json
//! autodnnchip exp      <fig7|fig8|fig9|fig10|table6|table7|table8|
//!                       fig11|fig12|fig13|fig14|fig15|all> [--seed N]
//! autodnnchip validate [--artifacts DIR]
//! ```

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};
use autodnnchip::builder::Spec;
use autodnnchip::coordinator::{self, MoveSetChoice, RunConfig};
use autodnnchip::dnn::zoo;
use autodnnchip::predictor::{predict_coarse, simulate};
use autodnnchip::templates::{HwConfig, TemplateId};
use autodnnchip::util::cli::Args;
use autodnnchip::util::table::{f, Table};
use autodnnchip::{experiments, runtime};

fn main() -> ExitCode {
    let args = Args::from_env();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.first().map(|s| s.as_str()) {
        Some("list-models") => {
            let mut t = Table::new("model zoo", &["name", "layers", "params (M)", "MACs (M)"]);
            for name in zoo::all_names() {
                let m = zoo::by_name(&name).unwrap();
                let s = m.stats()?;
                t.row(vec![
                    name,
                    m.layers.len().to_string(),
                    f(s.total_params as f64 / 1e6, 3),
                    f(s.total_macs as f64 / 1e6, 1),
                ]);
            }
            print!("{}", t.render());
            Ok(())
        }
        Some("predict") => cmd_predict(args),
        Some("build") => cmd_build(args),
        Some("exp") => cmd_exp(args),
        Some("validate") => cmd_validate(args),
        Some(other) => bail!("unknown command '{other}'"),
        None => {
            eprintln!(
                "usage: autodnnchip <list-models|predict|build|exp|validate> [flags]\n\
                 see `rust/src/main.rs` docs for details"
            );
            Ok(())
        }
    }
}

fn cmd_predict(args: &Args) -> Result<()> {
    let model_name = args.flag_or("model", "SK");
    let m = zoo::by_name(&model_name).ok_or_else(|| anyhow!("unknown model '{model_name}'"))?;
    let tmpl = TemplateId::by_name(&args.flag_or("template", "hetero_dw_pw"))
        .ok_or_else(|| anyhow!("unknown template"))?;
    let tech_name = args.flag_or("tech", "ultra96");
    let tech = autodnnchip::ip::tech::by_name(&tech_name).ok_or_else(|| anyhow!("unknown tech"))?;
    let mut cfg = if tech.fpga.is_some() { HwConfig::ultra96_default() } else { HwConfig::asic_default() };
    cfg.tech = tech;
    cfg.unroll = args.flag_usize("unroll", cfg.unroll);
    cfg.pipeline = args.flag_u64("pipeline", cfg.pipeline);
    let g = tmpl.build(&m, &cfg)?;
    let coarse = predict_coarse(&g, &cfg.tech)?;
    let fine = simulate(&g, cfg.tech.costs.leakage_mw, false)?;
    let mut t = Table::new(
        &format!("Chip Predictor — {model_name} on {}", tmpl.name()),
        &["metric", "coarse", "fine"],
    );
    t.row(vec!["latency (ms)".into(), f(coarse.latency_ms, 3), f(fine.latency_ms, 3)]);
    t.row(vec!["energy (µJ)".into(), f(coarse.energy_uj(), 1), f(fine.energy_pj / 1e6, 1)]);
    t.row(vec!["fps".into(), f(coarse.fps(), 1), f(1000.0 / fine.latency_ms, 1)]);
    t.row(vec!["DSP".into(), coarse.resources.dsp.to_string(), "-".into()]);
    t.row(vec!["BRAM18K".into(), coarse.resources.bram18k.to_string(), "-".into()]);
    t.row(vec!["SRAM (KB)".into(), f(coarse.resources.sram_kb, 1), "-".into()]);
    t.row(vec!["multipliers".into(), coarse.resources.multipliers.to_string(), "-".into()]);
    print!("{}", t.render());
    Ok(())
}

fn cmd_build(args: &Args) -> Result<()> {
    let cfg = if let Some(path) = args.flag("config") {
        RunConfig::from_file(path)?
    } else {
        let backend = args.flag_or("backend", "fpga");
        let spec = match backend.as_str() {
            "fpga" => Spec::ultra96_object_detection(),
            "asic" => Spec::asic_vision(),
            other => bail!("unknown backend '{other}'"),
        };
        let moves = match args.flag_or("moves", "full").as_str() {
            "legacy" => MoveSetChoice::Legacy,
            "full" => MoveSetChoice::Full,
            other => bail!("unknown move set '{other}' (expected 'legacy' or 'full')"),
        };
        RunConfig {
            model: args.flag_or("model", "SK"),
            // `--model-json path.json` imports a framework-export model
            // instead of naming a zoo entry.
            model_json: args.flag("model-json").map(|s| s.to_string()),
            spec,
            n2: args.flag_usize("n2", 4),
            n_opt: args.flag_usize("n-opt", 2),
            moves,
            out_dir: args.flag("out").map(|s| s.to_string()),
            rtl_out: args.flag("rtl-out").map(|s| s.to_string()),
        }
    };
    let summary = coordinator::run(&cfg)?;
    println!("{}", summary.result_json.pretty());
    if summary.build.survivors.is_empty() {
        bail!("no design survived DSE + PnR");
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .subcommand
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("usage: exp <id|all>"))?;
    let seed = args.flag_usize("seed", 0xA070) as u64;
    let results = PathBuf::from(args.flag_or("results", "results"));
    let ids: Vec<&str> = if id == "all" { experiments::all_ids() } else { vec![id] };
    for id in ids {
        let t0 = std::time::Instant::now();
        let rep = experiments::run(id, seed).with_context(|| format!("experiment {id}"))?;
        rep.save(&results)?;
        println!("{}", rep.text);
        println!("[{} done in {:.1}s; results/{}.json written]\n", id, t0.elapsed().as_secs_f64(), id);
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.flag_or("artifacts", "artifacts"));
    let rt = runtime::Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    for name in rt.artifact_names() {
        let loaded = rt.load(&name)?;
        println!("  {name}: inputs {:?} → {} outputs", loaded.meta.input_shapes, loaded.meta.num_outputs);
    }
    println!("all artifacts compile under PJRT");
    Ok(())
}
